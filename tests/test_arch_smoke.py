"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + finiteness.

The FULL assigned configs are exercised only via the dry-run (ShapeDtypeStruct
lowering, no allocation) — see test_dryrun.py.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import arch_ids, get_config
from repro.launch.train import scaled_lm_config

LM_ARCHS = [a for a in arch_ids() if get_config(a).family == "lm"]
RS_ARCHS = [a for a in arch_ids() if get_config(a).family == "recsys"]


def _finite(tree):
    return all(np.isfinite(np.asarray(l, np.float32)).all() for l in jax.tree.leaves(tree))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch, rng):
    from repro.models.transformer import (
        init_lm_params, lm_loss, init_kv_cache, lm_decode_step,
    )

    spec = get_config(arch)
    cfg = scaled_lm_config(spec.config, 0.05)
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)).astype(np.int32))
    batch = {"tokens": toks, "labels": toks}
    (loss, m), grads = jax.jit(
        jax.value_and_grad(lambda p: lm_loss(p, batch, cfg), has_aux=True)
    )(params)
    assert np.isfinite(float(loss)), arch
    assert _finite(grads), arch

    # one decode step with a KV cache
    cache = init_kv_cache(cfg, 2, 64)
    logits, cache = jax.jit(
        lambda p, c, t, l: lm_decode_step(p, c, t, l, cfg)
    )(params, cache, toks[:, 0], jnp.zeros(2, jnp.int32))
    assert logits.shape == (2, cfg.vocab_pad)
    assert _finite(logits)


def test_nequip_smoke(rng):
    from repro.data.graph import molecule_batch, synthetic_graph, NeighborSampler
    from repro.models.nequip import (
        NequIPConfig, init_nequip_params, nequip_loss,
    )

    # molecule (graph_energy)
    cfg = NequIPConfig("s", n_layers=2, channels=8, n_rbf=4, d_feat=16,
                       n_out=1, task="graph_energy")
    p = init_nequip_params(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in molecule_batch(4, 8, 16, 16).items()}
    loss, _ = jax.jit(lambda p: nequip_loss(p, batch, cfg))(p)
    assert np.isfinite(float(loss))

    # sampled-subgraph node classification (real neighbor sampler)
    g = synthetic_graph(500, 8, 12, 5, seed=1)
    sampler = NeighborSampler(g, fanout=(3, 2))
    sub = sampler.sample(np.arange(16))
    cfg2 = NequIPConfig("s2", n_layers=2, channels=8, n_rbf=4, d_feat=12,
                        n_out=5, task="node_class")
    p2 = init_nequip_params(jax.random.PRNGKey(1), cfg2)
    batch2 = {k: jnp.asarray(v) for k, v in sub.items()}
    loss2, _ = jax.jit(lambda p: nequip_loss(p, batch2, cfg2))(p2)
    assert np.isfinite(float(loss2))
    # static shapes as promised by the sampler
    assert sub["node_feats"].shape[0] == 16 * (1 + 3 + 6)
    assert sub["edge_index"].shape[1] == 16 * 3 * (1 + 2)


@pytest.mark.parametrize("arch", RS_ARCHS)
def test_recsys_smoke(arch, rng):
    from repro.models import recsys as R

    spec = get_config(arch)
    cfg = spec.config
    key = jax.random.PRNGKey(0)
    if isinstance(cfg, R.XDeepFMConfig):
        cfg = dataclasses.replace(cfg, rows_per_field=1000, cin_layers=(16, 16),
                                  mlp_layers=(32,))
        p = R.init_xdeepfm_params(key, cfg)
        batch = {
            "ids": jnp.asarray(rng.integers(0, cfg.n_sparse * 1000, (16, cfg.n_sparse))),
            "label": jnp.asarray(rng.integers(0, 2, 16)),
        }
        loss, _ = jax.jit(lambda p: R.xdeepfm_loss(p, batch, cfg))(p)
    elif isinstance(cfg, R.WideDeepConfig):
        cfg = dataclasses.replace(cfg, rows_per_field=1000, mlp_layers=(32, 16))
        p = R.init_widedeep_params(key, cfg)
        batch = {
            "ids": jnp.asarray(rng.integers(0, cfg.n_sparse * 1000, (16, cfg.n_sparse))),
            "label": jnp.asarray(rng.integers(0, 2, 16)),
        }
        loss, _ = jax.jit(lambda p: R.widedeep_loss(p, batch, cfg))(p)
    elif isinstance(cfg, R.TwoTowerConfig):
        cfg = dataclasses.replace(cfg, n_items=2000, n_user_feats=1000,
                                  feat_dim=16, embed_dim=16, tower_mlp=(32, 16))
        p = R.init_twotower_params(key, cfg)
        batch = {
            "user_hist": jnp.asarray(rng.integers(0, 2000, (8, cfg.user_hist_len))),
            "item_feats": jnp.asarray(rng.integers(0, 1000, (8, cfg.item_n_feats))),
        }
        loss, _ = jax.jit(lambda p: R.twotower_loss(p, batch, cfg))(p)
        vals, idx = R.twotower_retrieve(
            p,
            {"user_hist": batch["user_hist"][:1],
             "cand_embeds": jnp.asarray(rng.standard_normal((512, cfg.embed_dim)), jnp.float32)},
            cfg, k=7,
        )
        assert idx.shape == (7,)
    else:  # bert4rec
        cfg = dataclasses.replace(cfg, n_items=500, seq_len=16)
        p = R.init_bert4rec_params(key, cfg)
        seq = jnp.asarray(rng.integers(1, 500, (4, 16)).astype(np.int32))
        mask = jnp.asarray((rng.random((4, 16)) < 0.2).astype(np.int32))
        batch = {"seq": jnp.where(mask == 1, cfg.n_items + 1, seq),
                 "labels": seq, "mask": mask}
        loss, _ = jax.jit(lambda p: R.bert4rec_loss(p, batch, cfg))(p)
        vals, idx = R.bert4rec_serve(p, seq, cfg, k=5)
        assert idx.shape == (4, 5)
    assert np.isfinite(float(loss)), arch


def test_all_40_cells_buildable():
    """Every (arch x shape) cell must construct its step + specs (no
    compile here — the dry-run covers that in a subprocess)."""
    from repro.configs import all_cells
    from repro.launch.steps import build_cell

    cells = all_cells()
    assert len(cells) == 40
    for arch, shape in cells:
        cell = build_cell(arch, shape)
        assert cell.fn is not None
        assert len(jax.tree.leaves(cell.arg_specs)) > 0
