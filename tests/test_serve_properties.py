"""Hypothesis properties for the serving front end's closed-loop contract.

Three invariants, for ANY interleaving of ingest batches and query bursts
the strategy draws:

  1. **Never lose an acked write** — every ingest ticket that resolved
     (the ack) is visible after a forced reopen: the engine's total doc
     count equals seed + sum(acked batch sizes).
  2. **Never reorder a client's responses** — tickets submitted in order
     by one client resolve bound to non-decreasing wave numbers (FIFO
     through the dispatcher), whatever waves they coalesce into.
  3. **Waves preserve per-request k and filters** — each response is
     bit-identical to a serial oracle run at the response's OWN bound
     snapshot with the request's OWN ``k`` and query (filters, facets),
     even though the wave executed fused at the wave-max ``k``.

``hypothesis`` is an optional test dependency (same convention as
``test_wal_torn.py``): the module skips itself when absent; CI installs it
via requirements-test.txt.  ``tests/test_serve_frontend.py`` carries
deterministic twins of these scenarios so the contract stays covered
either way.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

pytestmark = pytest.mark.serve

from repro.core import ShardedEngine
from repro.core.search import FacetQuery, RangeQuery, TermQuery
from repro.serve import SearchFrontend

TOKENS = [f"w{i}" for i in range(8)]
SEED_DOCS = 40


def _docs(n0, size):
    """Deterministic batch of ``size`` docs starting at global doc ``n0``:
    a recognisable token soup + month doc values (facet/range fodder)."""
    out = []
    for j in range(size):
        n = n0 + j
        toks = " ".join(TOKENS[(n + i) % len(TOKENS)] for i in range(1 + n % 3))
        out.append(({"body": f"{toks} common"}, {"month": n % 12}))
    return out


def _query(fam, tok):
    if fam == 0:
        return TermQuery("body", TOKENS[tok])
    if fam == 1:
        return RangeQuery("month", tok % 12, 11)
    return FacetQuery(TermQuery("body", "common"), "month", 12)


# one op per draw: ("ingest", size) or ("burst", [(fam, tok, k), ...])
_op = st.one_of(
    st.tuples(st.just("ingest"), st.integers(min_value=1, max_value=12)),
    st.tuples(
        st.just("burst"),
        st.lists(
            st.tuples(
                st.integers(0, 2),           # query family
                st.integers(0, len(TOKENS) - 1),
                st.integers(1, 15),          # per-request k
            ),
            min_size=1,
            max_size=6,
        ),
    ),
)


@settings(max_examples=15, deadline=None)
@given(ops=st.lists(_op, min_size=1, max_size=8))
def test_closed_loop_invariants(ops):
    eng = ShardedEngine("ram", n_shards=2)
    eng.add_documents(_docs(0, SEED_DOCS))
    eng.flush()
    eng.commit()
    eng.reopen()
    fe = SearchFrontend(eng, max_wave=4, reopen_lag_docs=4, reopen_lag_s=0.0)
    try:
        n_docs = SEED_DOCS
        acked = 0
        client_reqs = []  # one logical client: submission order matters
        ingest_tickets = []
        for op, payload in ops:
            if op == "ingest":
                ingest_tickets.append(
                    (payload, fe.submit_ingest(_docs(n_docs, payload)))
                )
                n_docs += payload
            else:
                for fam, tok, k in payload:
                    client_reqs.append(fe.submit(_query(fam, tok), k=k))
        fe.drain(30.0)

        # 1. never lose an acked write
        for size, t in ingest_tickets:
            assert len(t.result(30.0)) == size  # every accepted batch acked
            acked += size
        fe.reopen(timeout=30.0)
        td = fe.search(RangeQuery("month", 0, 11), k=1, timeout=30.0)
        assert td.total_hits == SEED_DOCS + acked

        # 2. never reorder a client's responses
        for r in client_reqs:
            r.result(30.0)
        waves = [r.wave for r in client_reqs]
        assert waves == sorted(waves)

        # 3. per-request k + filters survive coalescing: serial oracle at
        # the bound snapshot, bit-identical
        for r in client_reqs:
            ref = r.searcher.search_batch([r.query], k=r.k)[0]
            got = r.result(30.0)
            ctx = f"{r.query!r} k={r.k} wave={r.wave}"
            assert got.total_hits == ref.total_hits, ctx
            np.testing.assert_array_equal(got.doc_ids, ref.doc_ids, err_msg=ctx)
            np.testing.assert_array_equal(got.scores, ref.scores, err_msg=ctx)
            if isinstance(r.query, FacetQuery):
                np.testing.assert_array_equal(got.facets, ref.facets, err_msg=ctx)
    finally:
        fe.close()
        eng.close()
