"""Segment lifecycle: point-in-time snapshots, merge policy, file GC.

The paper's NRT numbers (Fig 4a/4b) assume an open searcher is a true
point-in-time snapshot while the writer flushes, deletes, and merges
underneath it, and that merged-away segments are eventually reclaimed on
both persistence paths.  This suite pins:

  * point-in-time: a searcher opened before any delete/flush/merge/commit
    sequence returns bit-identical ``search_batch`` results afterward;
  * buffered-delete ordering: ``delete_by_term`` applies only to docs
    buffered BEFORE the call (Lucene semantics);
  * pre-reopen visibility: deletes to flushed segments are invisible to an
    open searcher until the next reopen;
  * crash safety of committed deletes (generational ``.liv`` files);
  * RAMDirectory snapshot safety and full crash cleanup;
  * GC invariants: ``list_segments()`` == live infos and storage bytes
    bounded after many flush+merge cycles on all three directory kinds;
  * TieredMergePolicy unit behavior (tier overflow, deletes trigger,
    merge-on-commit).
"""

import numpy as np
import pytest

from repro.core import SearchEngine
from repro.core.engine import make_directory
from repro.core.lifecycle import SegmentInfos, TieredMergePolicy
from repro.core.search import BooleanQuery, RangeQuery, TermQuery
from repro.data.corpus import CorpusConfig, synthetic_corpus, _word


def _fill(eng, n=30, prefix="alpha", start=0):
    for i in range(start, start + n):
        eng.add(
            {"body": f"{prefix} token{i % 7} common"},
            {"month": i % 12},
        )


def _topdocs_key(td):
    return (td.total_hits, td.doc_ids.tolist(), td.scores.tolist())


QUERIES = [
    TermQuery("body", "common"),
    TermQuery("body", "token3"),
    BooleanQuery((TermQuery("body", "token1"), TermQuery("body", "common")), "and"),
    RangeQuery("month", 2, 9),
]


# ---------------------------------------------------------------------------
# Point-in-time suite (tentpole)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["ram", "fs-ssd", "byte-pmem"])
def test_searcher_is_point_in_time_snapshot(tmp_path, kind):
    """A searcher opened before delete/flush/merge/commit returns
    bit-identical search_batch results afterward."""
    eng = SearchEngine(kind, str(tmp_path / "pit"))
    eng.writer.merge_factor = 3
    for i in range(8):
        _fill(eng, 10, start=i * 10)
        eng.flush()
    eng.reopen()
    searcher = eng.searcher
    before = [_topdocs_key(td) for td in searcher.search_batch(QUERIES, k=20)]

    # now mutate aggressively underneath the open searcher
    eng.delete("body", "token3")          # deletes on flushed segments
    _fill(eng, 25, prefix="beta", start=80)
    eng.flush()                            # flush (+ tiered merge cascade)
    eng.delete("body", "token1")
    eng.commit()                           # commit + file GC
    _fill(eng, 15, prefix="gammaonly", start=105)
    eng.flush()
    eng.commit()

    after = [_topdocs_key(td) for td in searcher.search_batch(QUERIES, k=20)]
    assert before == after  # bit-identical: ids, scores, totals

    # while the NEW searcher sees all of it: every pre-delete token3 doc is
    # gone; only docs added after the delete_by_term call still match
    eng.reopen()
    td = eng.search(TermQuery("body", "token3"), k=5)
    assert td.total_hits == sum(1 for i in range(80, 120) if i % 7 == 3)


def test_open_searcher_survives_merge_rebasing():
    """Merges must not rebase base_doc in place on segments an open
    searcher holds (the old ``_maybe_merge`` bug)."""
    eng = SearchEngine("ram")
    eng.writer.merge_factor = 3
    for i in range(3):
        _fill(eng, 10, start=i * 10)
        eng.flush()
    _fill(eng, 10, start=30)  # buffered; flushing it will overflow the tier
    eng.reopen()
    searcher = eng.searcher
    bases_before = [s.base_doc for s in searcher.segments]
    before = _topdocs_key(searcher.search(TermQuery("body", "common"), k=40))

    eng.flush()  # 4th segment crosses merge_factor=3: triggers the merge
    assert eng.writer.merge_scheduler.stats.merges > 0
    assert [s.base_doc for s in searcher.segments] == bases_before
    assert _topdocs_key(searcher.search(TermQuery("body", "common"), k=40)) == before


def test_delete_invisible_until_reopen():
    """delete_by_term must not leak into the current searcher before
    reopen (the shared-Segment live-swap bug)."""
    eng = SearchEngine("ram")
    _fill(eng, 30)
    eng.reopen()
    searcher = eng.searcher
    before = searcher.search(TermQuery("body", "token3"), k=30)
    assert before.total_hits > 0

    eng.delete("body", "token3")
    mid = searcher.search(TermQuery("body", "token3"), k=30)
    assert _topdocs_key(mid) == _topdocs_key(before)  # contract: not yet

    eng.reopen()
    assert eng.search(TermQuery("body", "token3")).total_hits == 0


def test_buffered_delete_watermark():
    """A buffered delete applies only to docs added BEFORE the
    delete_by_term call (Lucene semantics), not to later adds."""
    eng = SearchEngine("ram")
    eng.add({"body": "victim target"})
    eng.add({"body": "victim other"})
    eng.delete("body", "victim")
    eng.add({"body": "victim survivor"})  # added after the delete
    eng.reopen()
    td = eng.search(TermQuery("body", "victim"), k=5)
    assert td.total_hits == 1
    assert eng.search(TermQuery("body", "survivor")).total_hits == 1
    assert eng.search(TermQuery("body", "target")).total_hits == 0


def test_repeat_delete_is_a_noop():
    """Deleting an already-deleted term must not report phantom deletions,
    write a new .liv generation, or publish a new snapshot."""
    eng = SearchEngine("ram")
    _fill(eng, 30)
    eng.reopen()
    n1 = eng.delete("body", "token3")
    assert n1 > 0
    gen = eng.writer.generation
    assert eng.delete("body", "token3") == 0  # nothing left to delete
    assert eng.writer.generation == gen  # no snapshot churn, no reopen cost


def test_infos_snapshot_immutability():
    eng = SearchEngine("ram")
    _fill(eng, 20)
    eng.flush()
    infos = eng.writer.infos
    assert isinstance(infos, SegmentInfos)
    gen = infos.generation
    names = infos.names()
    lives = [s.live for s in infos.segments]
    _fill(eng, 20, start=20)
    eng.flush()
    eng.delete("body", "token1")
    # the old snapshot is untouched: same object graph, same bitmaps
    assert infos.generation == gen
    assert infos.names() == names
    assert all(a is b for a, b in zip(lives, [s.live for s in infos.segments]))
    assert eng.writer.infos.generation > gen


# ---------------------------------------------------------------------------
# TieredMergePolicy / MergeScheduler
# ---------------------------------------------------------------------------


def _seg_stub(name, n_docs, n_dead=0):
    """Minimal real segment built through the public path."""
    from repro.core.segment import build_segment

    live = np.ones(n_docs, dtype=bool)
    if n_dead:
        live[:n_dead] = False
    return build_segment(
        name, 0, {7: [(i, 1, [0]) for i in range(n_docs)]},
        [1] * n_docs, {}, live,
    )


def test_policy_tier_overflow_selects_oldest():
    pol = TieredMergePolicy(segments_per_tier=3, max_merge_at_once=3)
    segs = tuple(_seg_stub(f"_s{i}", 10) for i in range(4))
    infos = SegmentInfos(1, segs)
    specs = pol.find_merges(infos)
    assert len(specs) == 1
    assert specs[0].reason == "tier"
    assert list(specs[0].segments) == ["_s0", "_s1", "_s2"]


def test_policy_respects_size_tiers():
    """A big merged segment must not be dragged into small-segment merges
    (the old prefix merge rewrote everything repeatedly)."""
    pol = TieredMergePolicy(segments_per_tier=3, max_merge_at_once=3)
    segs = (_seg_stub("_m0", 500),) + tuple(_seg_stub(f"_s{i}", 10) for i in range(3))
    infos = SegmentInfos(1, segs)
    assert pol.find_merges(infos) == []  # small tier at capacity, big alone
    segs = segs + (_seg_stub("_s3", 10),)
    specs = pol.find_merges(SegmentInfos(2, segs))
    assert len(specs) == 1
    assert "_m0" not in specs[0].segments  # only the small tier merges


def test_policy_deletes_percentage_trigger():
    pol = TieredMergePolicy(segments_per_tier=10, deletes_pct_allowed=20.0)
    healthy = _seg_stub("_s0", 100, n_dead=10)
    sick = _seg_stub("_s1", 100, n_dead=40)
    specs = pol.find_merges(SegmentInfos(1, (healthy, sick)))
    assert [s for s in specs if s.reason == "deletes"] == specs
    assert specs[0].segments == ("_s1",)


def test_deletes_rewrite_drops_dead_docs():
    """A segment past the deletes threshold is rewritten at the next
    flush/commit and its dead docs reclaimed."""
    eng = SearchEngine("ram")
    for i in range(40):
        eng.add({"body": ("drop " if i % 2 else "keep ") + f"tok{i % 5}"})
    eng.flush()
    eng.delete("body", "drop")  # 50% of the segment dies
    eng.commit()                # deletes-triggered rewrite runs here
    stats = eng.writer.merge_scheduler.stats
    assert stats.by_reason.get("deletes", 0) >= 1
    assert stats.docs_dropped >= 20
    [seg] = eng.writer.segments
    assert seg.n_docs == seg.n_live == 20
    eng.reopen()
    assert eng.search(TermQuery("body", "keep"), k=40).total_hits == 20


def test_merge_on_commit_consolidates_small_tier():
    eng = SearchEngine("ram")
    eng.writer.merge_policy.merge_on_commit = True
    for i in range(3):  # 3 tiny segments, below the overflow threshold
        _fill(eng, 5, start=i * 5)
        eng.flush()
    assert len(eng.writer.segments) == 3
    eng.commit()
    assert len(eng.writer.segments) == 1
    assert eng.writer.merge_scheduler.stats.by_reason.get("commit", 0) == 1
    eng.reopen()
    assert eng.search(TermQuery("body", "common"), k=20).total_hits == 15


def test_merge_cascade_keeps_segment_count_logarithmic():
    eng = SearchEngine("ram")
    eng.writer.merge_factor = 3
    for i in range(60):
        eng.add({"body": f"tok{i % 11} shared"}, {"month": i % 12})
        if i % 5 == 4:
            eng.flush()
    assert len(eng.writer.segments) <= 6  # 12 flushes, tiered down
    eng.reopen()
    assert eng.search(TermQuery("body", "shared"), k=60).total_hits == 60


# ---------------------------------------------------------------------------
# Crash recovery: generational .liv (satellite 3) + RAMDirectory (satellite 4)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["fs-ssd", "fs-pmem"])
def test_committed_deletes_survive_crash_after_later_delete(tmp_path, kind):
    """delete -> commit -> delete -> crash: the committed delete must
    survive (the old in-place .liv overwrite lost it)."""
    eng = SearchEngine(kind, str(tmp_path / "d"))
    _fill(eng, 30)
    eng.commit()
    eng.delete("body", "token3")   # committed delete
    eng.commit()
    eng.delete("body", "token5")   # uncommitted delete dirties the bitmap
    n_tok3 = eng.search(TermQuery("body", "token3"), k=40).total_hits  # == 0 already
    eng2 = eng.crash_and_recover()
    assert eng2.search(TermQuery("body", "token3")).total_hits == 0   # kept
    tok5 = eng2.search(TermQuery("body", "token5"), k=10)
    assert tok5.total_hits > 0  # uncommitted delete rolled back
    # live doc count == 30 minus exactly the committed token3 deletes
    assert eng2.search(TermQuery("body", "common"), k=40).total_hits == 30 - (30 // 7 + (1 if 3 < 30 % 7 else 0))
    assert n_tok3 == 0


def test_fs_crash_does_not_reuse_liv_generation(tmp_path):
    """Restart -> delete -> crash -> delete must not overwrite the committed
    .liv generation: crash() rebuilds the generation map from disk (a fresh
    process has an empty _synced_liv, which previously made the post-crash
    writer reuse gen 0 in place)."""
    from repro.core.directory import FSDirectory

    p = str(tmp_path / "gen")
    eng = SearchEngine("fs-ssd", p)
    _fill(eng, 30)
    eng.commit()
    eng.delete("body", "token3")
    eng.commit()
    eng2 = SearchEngine(FSDirectory(p))  # fresh process over the same dir
    eng2.delete("body", "token5")        # un-fsynced generation
    eng3 = eng2.crash_and_recover()      # token5 delete is lost...
    assert eng3.search(TermQuery("body", "token3")).total_hits == 0
    eng3.delete("body", "token5")        # must open a NEW generation
    eng4 = eng3.crash_and_recover()      # ...whose loss can't take token3 along
    assert eng4.search(TermQuery("body", "token3")).total_hits == 0
    assert eng4.search(TermQuery("body", "token5"), k=10).total_hits > 0


def test_fs_legacy_ungenerational_liv_still_readable(tmp_path):
    """A pre-generational '{name}.liv' file parses as generation -1: it is
    read until the first new write supersedes it."""
    import os

    from repro.core.directory import FSDirectory

    p = str(tmp_path / "legacy")
    eng = SearchEngine("fs-ssd", p)
    _fill(eng, 30)
    eng.commit()
    eng.delete("body", "token3")
    eng.commit()
    [liv] = [f for f in os.listdir(p) if f.endswith(".liv")]
    base = liv[:-4].rsplit("_", 1)[0]
    os.rename(os.path.join(p, liv), os.path.join(p, base + ".liv"))
    eng2 = SearchEngine(FSDirectory(p))
    assert eng2.search(TermQuery("body", "token3")).total_hits == 0
    eng2.delete("body", "token5")
    eng2.commit()
    eng3 = SearchEngine(FSDirectory(p))
    assert eng3.search(TermQuery("body", "token5")).total_hits == 0
    assert eng3.search(TermQuery("body", "token3")).total_hits == 0


def test_byte_compaction_swaps_heap_file_atomically(tmp_path):
    """Compaction re-packs into a fresh heap file and flips the root record
    atomically: exactly one heap file remains (the rooted one) and a fresh
    process recovers from it."""
    import json
    import os

    from repro.core.directory import ByteAddressableDirectory

    p = str(tmp_path / "swap")
    eng = SearchEngine("byte-pmem", p)
    eng.writer.merge_factor = 3
    n = _churn(eng, 20, docs_per_flush=10, commit_every=3)
    d = eng.directory
    assert d.gc_info["compactions"] > 0
    with open(os.path.join(p, "root.json")) as f:
        root = json.load(f)
    pmems = [f for f in os.listdir(p) if f.endswith(".pmem")]
    assert pmems == [root["heap"]]
    eng2 = SearchEngine(ByteAddressableDirectory(p))
    assert eng2.search(TermQuery("body", "common"), k=5).total_hits == n


def test_ram_directory_snapshot_safe_and_clean_crash():
    dir_ = make_directory("ram")
    eng = SearchEngine(dir_)
    _fill(eng, 20)
    eng.commit()
    seg = dir_._segs[eng.writer.segments[0].name]
    # read_segment must not mutate the stored segment's base_doc
    view = dir_.read_segment(seg.name, 12345)
    assert view.base_doc == 12345 and seg.base_doc != 12345 or view is not seg
    assert dir_._segs[seg.name].base_doc == seg.base_doc
    # write_live must swap a clone, not mutate the stored object
    old_live = seg.live
    live = old_live.copy()
    live[0] = False
    dir_.write_live(seg.name, live)
    assert seg.live is old_live
    assert dir_._segs[seg.name].live is live
    # crash clears ALL commit state, including meta
    dir_.crash()
    assert dir_._segs == {} and dir_._meta == {} and dir_.latest_commit() is None


# ---------------------------------------------------------------------------
# GC invariants (tentpole) — all three persistence paths
# ---------------------------------------------------------------------------


def _churn(eng, cycles, docs_per_flush=20, commit_every=5):
    """Sustained ingest: flush+merge cycles with periodic commit+GC."""
    n = 0
    for c in range(cycles):
        for _ in range(docs_per_flush):
            eng.add({"body": f"cycle{c % 7} tok{n % 13} common"}, {"month": n % 12})
            n += 1
        eng.flush()
        if (c + 1) % commit_every == 0:
            eng.commit()
    eng.commit()
    return n


@pytest.mark.parametrize("kind", ["ram", "fs-ssd", "byte-pmem"])
def test_gc_list_segments_matches_live_infos(tmp_path, kind):
    eng = SearchEngine(kind, str(tmp_path / "gc"))
    eng.writer.merge_factor = 4
    _churn(eng, 20)
    assert eng.writer.merge_scheduler.stats.merges > 0
    assert sorted(eng.directory.list_segments()) == sorted(eng.writer.infos.names())
    assert eng.writer.gc_stats["reclaimed_bytes"] > 0


def test_fs_no_orphan_files_after_post_merge_commit(tmp_path):
    import os

    eng = SearchEngine("fs-ssd", str(tmp_path / "fs"))
    eng.writer.merge_factor = 3
    _fill(eng, 60)
    eng.flush()
    eng.delete("body", "token2")
    _churn(eng, 12, docs_per_flush=10)
    live = set(eng.writer.infos.names())
    files = os.listdir(str(tmp_path / "fs"))
    seg_files = {f[:-4] for f in files if f.endswith(".seg")}
    assert seg_files == live  # no orphan .seg
    for f in files:
        if f.endswith(".liv"):
            base = f[:-4].rsplit("_", 1)[0]
            assert base in live  # no orphan .liv
    # keep-only-last commit-point policy: exactly one manifest remains
    assert sum(1 for f in files if f.startswith("segments_")) == 1


def test_byte_path_heap_bounded_after_50_cycles(tmp_path):
    """Acceptance: after 50 flush+merge cycles the heap stays within 2x
    the live index and the TOC references no merged-away names."""
    eng = SearchEngine("byte-pmem", str(tmp_path / "by"))
    eng.writer.merge_factor = 4
    _churn(eng, 50, docs_per_flush=20, commit_every=5)
    d = eng.directory
    live_names = set(eng.writer.infos.names())
    assert set(d.list_segments()) == live_names
    live_bytes = sum(
        d.heap.extent(off) for e in d._toc.values() for off in e.values()
    )
    assert d.heap.tail <= 2 * live_bytes + 65536, (d.heap.tail, live_bytes)
    assert d.gc_info["compactions"] > 0
    assert d.gc_info["reclaimed_bytes"] > 0
    # the compacted index is still correct...
    eng.reopen()
    td = eng.search(TermQuery("body", "common"), k=10)
    assert td.total_hits == 1000
    # ...and still crash-consistent
    eng2 = eng.crash_and_recover()
    assert eng2.search(TermQuery("body", "common"), k=10).total_hits == 1000


def test_byte_path_gc_deferred_while_views_loaned(tmp_path):
    """Zero-copy reader views pin the heap: compaction is deferred until
    the loaned arrays die (Lucene: files are deleted only when readers
    close)."""
    path = str(tmp_path / "loan")
    eng = SearchEngine("byte-pmem", path)
    eng.writer.merge_factor = 3
    _fill(eng, 40)
    eng.commit()
    d = eng.directory
    # an external reader takes zero-copy views of the committed segment
    loaned = d.read_segment(eng.writer.infos.names()[0], 0)
    assert any(r() is not None for r in d._loans)
    before = d.gc_info["compactions"]
    _churn(eng, 12, docs_per_flush=10)  # plenty of merge garbage
    assert d.gc_info["compactions"] == before  # pinned: never moved bytes
    assert d.gc_info["deferred"] > 0
    live_before_release = int(loaned.live.sum())  # view stayed coherent
    assert live_before_release == 40
    del loaned  # reader closes -> loans die -> next gc may compact
    eng.commit()
    _churn(eng, 6, docs_per_flush=10)
    assert d.gc_info["compactions"] > before
    eng.reopen()
    assert eng.search(TermQuery("body", "common"), k=5).total_hits == 220


def test_byte_path_compaction_not_blocked_by_writer_recovery(tmp_path):
    """The writer's own recovered working set must not pin the heap: it
    opens host copies (open_for_write), so compaction keeps running on
    the restart path and heap usage stays bounded."""
    path = str(tmp_path / "restart")
    eng = SearchEngine("byte-pmem", path)
    _fill(eng, 40)
    eng.commit()
    eng = eng.crash_and_recover()  # writer reopens from the commit point
    eng.writer.merge_factor = 3
    d = eng.directory
    assert all(r() is None for r in d._loans)  # recovery took copies
    _churn(eng, 20, docs_per_flush=10, commit_every=3)
    assert d.gc_info["compactions"] > 0
    assert d.gc_info["deferred"] == 0
    live_bytes = sum(
        d.heap.extent(off) for e in d._toc.values() for off in e.values()
    )
    assert d.heap.tail <= 2 * live_bytes + 65536, (d.heap.tail, live_bytes)
    eng.reopen()
    assert eng.search(TermQuery("body", "common"), k=5).total_hits == 240


def test_gc_preserves_queryability_across_kinds(tmp_path):
    for kind in ("ram", "fs-ssd", "byte-pmem"):
        eng = SearchEngine(kind, str(tmp_path / f"q-{kind}"))
        eng.writer.merge_factor = 3
        n = _churn(eng, 15, docs_per_flush=12)
        eng.reopen()
        assert eng.search(TermQuery("body", "common"), k=5).total_hits == n
        # post-GC recovery from the commit point still works
        if kind != "ram":
            eng2 = eng.crash_and_recover()
            assert eng2.search(TermQuery("body", "common"), k=5).total_hits == n


def test_merge_warmup_makes_post_merge_reopen_cheap():
    """After a merge, reopen must upload nothing new: the merge listener
    already staged the merge output (proportional to merge output, not
    index size)."""
    eng = SearchEngine("ram")
    docs = list(synthetic_corpus(CorpusConfig(n_docs=220, vocab=300, seed=9)))
    for i, (fields, dv) in enumerate(docs):
        eng.add(fields, dv)
        if (i + 1) % 20 == 0:
            eng.flush()  # segment-per-20 cadence drives the tiered merge
            eng.reopen()
    stats = eng.device_cache.stats
    assert stats.merge_warmups >= 1
    uploads_before = stats.array_uploads
    eng.reopen()  # post-merge steady state: nothing left to upload
    assert stats.array_uploads == uploads_before
