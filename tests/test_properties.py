"""Hypothesis property tests on system invariants.

``hypothesis`` is an optional test dependency: skip the whole module when it
is absent rather than erroring the collection run.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import SearchEngine
from repro.core.search import TermQuery, BooleanQuery
from repro.models.recsys import embedding_bag
from repro.storage.heap import PersistentHeap

import jax.numpy as jnp

TOKENS = [f"w{i}" for i in range(12)]


def docs_strategy():
    doc = st.lists(st.sampled_from(TOKENS), min_size=1, max_size=12)
    return st.lists(doc, min_size=1, max_size=40)


@settings(max_examples=25, deadline=None)
@given(docs=docs_strategy(), flush_every=st.integers(1, 10))
def test_segmentation_invariance(docs, flush_every):
    """Search results are invariant to how docs are split into segments."""
    def build(fe):
        eng = SearchEngine("ram")
        for i, toks in enumerate(docs):
            eng.add({"body": " ".join(toks)}, {"month": i % 12})
            if (i + 1) % fe == 0:
                eng.flush()
        eng.reopen()
        return eng

    a = build(flush_every)
    b = build(len(docs) + 1)  # single segment
    for tok in TOKENS[:4]:
        # k >= n_docs: no truncation boundary, so 1-ulp FMA differences
        # between differently-shaped executables cannot change membership
        ta = a.search(TermQuery("body", tok), k=len(docs))
        tb = b.search(TermQuery("body", tok), k=len(docs))
        assert ta.total_hits == tb.total_hits
        np.testing.assert_allclose(ta.scores, tb.scores, rtol=1e-4)
        # identical ranking up to reordering within float32-equal scores
        key_a = sorted(zip(np.round(ta.scores, 5), ta.doc_ids))
        key_b = sorted(zip(np.round(tb.scores, 5), tb.doc_ids))
        assert key_a == key_b


@settings(max_examples=20, deadline=None)
@given(docs=docs_strategy())
def test_and_is_subset_of_or(docs):
    eng = SearchEngine("ram")
    for toks in docs:
        eng.add({"body": " ".join(toks)})
    eng.reopen()
    q_and = BooleanQuery((TermQuery("body", "w0"), TermQuery("body", "w1")), "and")
    q_or = BooleanQuery((TermQuery("body", "w0"), TermQuery("body", "w1")), "or")
    a = eng.search(q_and, k=50)
    o = eng.search(q_or, k=50)
    assert a.total_hits <= o.total_hits
    assert set(a.doc_ids.tolist()) <= set(o.doc_ids.tolist())


@settings(max_examples=20, deadline=None)
@given(
    data=st.data(),
    n_rows=st.integers(2, 30),
    dim=st.integers(1, 8),
)
def test_embedding_bag_equals_onehot_matmul(data, n_rows, dim):
    """EmbeddingBag == sum-of-one-hot matmul (the dense definition)."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    table = rng.standard_normal((n_rows, dim)).astype(np.float32)
    n_idx = data.draw(st.integers(1, 40))
    indices = rng.integers(0, n_rows, n_idx)
    n_bags = data.draw(st.integers(1, 6))
    cuts = np.sort(rng.integers(0, n_idx + 1, n_bags - 1)) if n_bags > 1 else np.array([], int)
    offsets = np.concatenate([[0], cuts, [n_idx]]).astype(np.int32)

    out = embedding_bag(jnp.asarray(table), jnp.asarray(indices), jnp.asarray(offsets))
    onehot = np.zeros((n_bags, n_rows), np.float32)
    for b in range(n_bags):
        for i in indices[offsets[b] : offsets[b + 1]]:
            onehot[b, i] += 1
    np.testing.assert_allclose(np.asarray(out), onehot @ table, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_heap_store_load_roundtrip(data, tmp_path_factory):
    """Byte path: arrays survive store -> barrier -> crash -> load."""
    tmp = tmp_path_factory.mktemp("heap")
    heap = PersistentHeap(str(tmp / "h.pmem"), 1 << 20)
    dtypes = [np.float32, np.int32, np.uint8, np.float64, np.bool_]
    arrays = []
    for i in range(data.draw(st.integers(1, 6))):
        dt = data.draw(st.sampled_from(dtypes))
        shape = tuple(
            data.draw(st.integers(1, 8)) for _ in range(data.draw(st.integers(1, 3)))
        )
        rng = np.random.default_rng(i)
        a = (rng.standard_normal(shape) * 10).astype(dt)
        arrays.append((heap.store(a), a))
    heap.barrier()
    uncommitted = heap.store(np.ones(4, np.float32))
    heap.truncate_to_committed()  # crash
    for off, a in arrays:
        np.testing.assert_array_equal(heap.load(off), a)
    heap.close()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_nequip_rotation_invariance(seed):
    """O(3) invariance of scalar outputs under random rotations+translation."""
    import jax
    from scipy.spatial.transform import Rotation
    from repro.models.nequip import NequIPConfig, init_nequip_params, nequip_forward

    cfg = NequIPConfig("t", n_layers=2, channels=4, n_rbf=4, d_feat=3, n_out=2)
    p = init_nequip_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    batch = {
        "node_feats": jnp.asarray(rng.standard_normal((10, 3)).astype(np.float32)),
        "positions": jnp.asarray(rng.standard_normal((10, 3)).astype(np.float32)),
        "edge_index": jnp.asarray(rng.integers(0, 10, (2, 24)).astype(np.int32)),
    }
    out = nequip_forward(p, batch, cfg)
    R = jnp.asarray(
        Rotation.random(random_state=seed % 1000).as_matrix(), jnp.float32
    )
    b2 = dict(batch)
    b2["positions"] = batch["positions"] @ R.T + jnp.asarray(
        rng.standard_normal(3).astype(np.float32)
    )
    out2 = nequip_forward(p, b2, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=2e-4)
