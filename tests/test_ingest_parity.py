"""Columnar ingest parity: the vectorized flush/merge pipeline must be
BIT-IDENTICAL to the reference (pre-columnar) implementations.

``build_segment_reference``/``merge_segments_reference`` are the oracles
(the per-term/per-posting Python loops the columnar path replaced); every
segment array — term ids, CSR pointers, postings, positions, doc values,
live bitmaps — must match exactly, across all three directory kinds and
through each kind's serialization round-trip.

Seeded tests always run; the hypothesis round-trip property runs when
hypothesis is installed (same optional-dependency policy as
test_properties.py, but the seeded coverage here never skips).
"""

import numpy as np
import pytest

from repro.core.engine import make_directory
from repro.core.segment import (
    build_segment,
    build_segment_reference,
    merge_segments,
    merge_segments_reference,
)
from repro.core.writer import IndexWriter

KINDS = ("ram", "fs-ssd", "byte-pmem")
TOKENS = [f"tok{i}" for i in range(40)]


def assert_segments_identical(a, b, ctx=""):
    assert a.name == b.name and a.base_doc == b.base_doc, ctx
    aa, ba = a.arrays(), b.arrays()
    assert set(aa) == set(ba), (ctx, set(aa) ^ set(ba))
    for k, va in aa.items():
        vb = ba[k]
        assert va.dtype == vb.dtype, (ctx, k, va.dtype, vb.dtype)
        assert va.shape == vb.shape, (ctx, k, va.shape, vb.shape)
        np.testing.assert_array_equal(va, vb, err_msg=f"{ctx}:{k}")


def random_docs(rng, n_docs):
    """Random (fields, doc_values) batches exercising the buffer's edge
    cases: empty fields, repeated tokens, sparse/late doc-value keys."""
    docs = []
    for i in range(n_docs):
        n_body = int(rng.integers(0, 25))
        body = " ".join(rng.choice(TOKENS, size=n_body)) if n_body else ""
        title = " ".join(rng.choice(TOKENS, size=int(rng.integers(0, 4))))
        dv = {}
        if rng.random() < 0.6:
            dv["month"] = int(rng.integers(0, 12))
        if rng.random() < 0.3:
            dv["late_key"] = int(rng.integers(0, 99))  # appears on some docs only
        docs.append(({"title": title, "body": body}, dv))
    return docs


def ingest(kind, path, docs, reference, deletes=(), flush_every=7):
    """Drive a writer end to end; returns (writer, directory)."""
    d = make_directory(kind, path)
    w = IndexWriter(d, merge_factor=3, use_reference_ingest=reference)
    dmap = dict(deletes)
    for i, (fields, dv) in enumerate(docs):
        w.add_document(fields, dv)
        if i in dmap:
            w.delete_by_term("body", dmap[i])
        if (i + 1) % flush_every == 0:
            w.flush()
    w.flush()
    return w, d


@pytest.mark.parametrize("kind", KINDS)
def test_pipeline_parity_flush_merge_roundtrip(kind, tmp_path):
    """Full add -> buffered-delete -> flush -> tiered-merge pipeline parity,
    read back through each directory's serialization."""
    rng = np.random.default_rng(7)
    docs = random_docs(rng, 60)
    deletes = [(11, "tok3"), (25, "tok0"), (40, "tok7")]
    wc, dc = ingest(kind, str(tmp_path / "col"), docs, False, deletes)
    wr, dr = ingest(kind, str(tmp_path / "ref"), docs, True, deletes)

    assert [s.name for s in wc.segments] == [s.name for s in wr.segments]
    assert len(wc.segments) >= 1
    merged_names = [s.name for s in wc.segments if s.name.startswith("_m")]
    assert merged_names, "scenario must exercise the merge path"
    base = 0
    for sc, sr in zip(wc.segments, wr.segments):
        # in-memory parity (what the searcher sees pre-serialization)
        assert_segments_identical(sc, sr, f"{kind}:mem:{sc.name}")
        # storage round-trip parity (packed FS codec / heap extents)
        rc = dc.read_segment(sc.name, base)
        rr = dr.read_segment(sr.name, base)
        assert_segments_identical(rc, rr, f"{kind}:disk:{sc.name}")
        base += sc.n_docs


@pytest.mark.parametrize("kind", KINDS)
def test_merge_parity_direct(kind, tmp_path):
    """merge_segments == merge_segments_reference on segments read back
    from each directory kind (deleted docs dropped, ids remapped)."""
    rng = np.random.default_rng(21)
    docs = random_docs(rng, 40)
    w, d = ingest(kind, str(tmp_path / "x"), docs, False, flush_every=9)
    w.delete_by_term("body", "tok1")
    segs = [d.read_segment(s.name, s.base_doc) for s in w.segments]
    # give read-back segments the writer's live bitmaps (deletes applied)
    segs = [r.with_live(s.live) for r, s in zip(segs, w.segments)]
    assert sum(s.n_docs - s.n_live for s in segs) > 0
    m_col = merge_segments("_m9", 0, segs)
    m_ref = merge_segments_reference("_m9", 0, segs)
    assert_segments_identical(m_col, m_ref, f"{kind}:merge")


def test_build_segment_dict_wrapper_parity():
    """The dict-buffer compat entry point routes through the columnar build
    and still matches the reference exactly (incl. unsorted doc lists)."""
    rng = np.random.default_rng(3)
    for trial in range(20):
        n_docs = int(rng.integers(1, 12))
        buffer = {}
        for th in rng.integers(1, 1 << 40, size=rng.integers(0, 8)):
            docs = sorted(set(rng.integers(0, n_docs, size=rng.integers(1, 6)).tolist()))
            plist = []
            for dl in docs:
                f = int(rng.integers(1, 5))
                plist.append((dl, f, rng.integers(0, 50, size=f).astype(np.int32)))
            buffer[int(th)] = plist
        doc_lens = rng.integers(0, 30, size=n_docs).tolist()
        dv = {"k": np.arange(n_docs, dtype=np.int32)}
        live = rng.random(n_docs) < 0.8
        a = build_segment("_s0", 0, buffer, doc_lens, dv, live.copy())
        b = build_segment_reference("_s0", 0, buffer, doc_lens, dv, live.copy())
        assert_segments_identical(a, b, f"trial{trial}")


def test_buffered_delete_watermark_parity():
    """Vectorized watermark application == reference nested loop: only docs
    buffered BEFORE each delete die, later docs with the term survive."""
    for kind_docs in (30, 55):
        rng = np.random.default_rng(kind_docs)
        docs = random_docs(rng, kind_docs)
        deletes = [(5, "tok2"), (6, "tok2"), (20, "tok4"), (21, "tok2")]
        wc, _ = ingest("ram", None, docs, False, deletes, flush_every=1000)
        wr, _ = ingest("ram", None, docs, True, deletes, flush_every=1000)
        for sc, sr in zip(wc.segments, wr.segments):
            assert_segments_identical(sc, sr, "watermark")


def test_ram_bytes_incremental_and_flush_trigger():
    """ram_bytes_used is maintained incrementally (O(1) read) and drives
    the flush_ram_mb auto-flush when enabled (default stays off)."""
    w = IndexWriter(make_directory("ram"))
    assert w.ram_bytes_used() == 0
    w.add_document({"body": "a b c a"}, {"month": 3})
    n1 = w.ram_bytes_used()
    assert n1 > 0
    w.add_document({"body": "d e"})
    assert w.ram_bytes_used() > n1
    w.flush()
    assert w.ram_bytes_used() == 0  # buffer reset
    # default off: large docs never auto-flush
    for _ in range(50):
        w.add_document({"body": "x " * 50})
    assert w.buffered_docs == 50

    wt = IndexWriter(make_directory("ram"), flush_ram_mb=0.001)  # ~1 KiB
    for _ in range(50):
        wt.add_document({"body": "y z " * 30})
    assert wt.buffered_docs < 50, "auto-flush never fired"
    assert wt.infos.total_docs + wt.buffered_docs == 50  # no docs lost
    wt.flush()
    assert wt.infos.total_docs == 50


def test_fs_packed_layout_and_legacy_npz_fallback(tmp_path):
    """New .seg files use the packed single-blob codec; pre-PR npz blobs
    still load (read-path backward compatibility)."""
    import io

    from repro.core.directory import _PACK_MAGIC, FSDirectory

    d = FSDirectory(str(tmp_path))
    w = IndexWriter(d)
    w.add_document({"body": "alpha beta alpha"}, {"month": 1})
    seg = w.flush()
    with open(tmp_path / f"{seg.name}.seg", "rb") as f:
        assert f.read(8) == _PACK_MAGIC
    rt = d.read_segment(seg.name, 0)
    assert_segments_identical(seg, rt, "packed-roundtrip")

    # legacy blob: what the pre-packing serializer produced
    buf = io.BytesIO()
    np.savez(buf, **seg.arrays())
    with open(tmp_path / "_s000099.seg", "wb") as f:
        f.write(buf.getvalue())
    legacy = d.read_segment("_s000099", 0)
    for k, v in seg.arrays().items():
        np.testing.assert_array_equal(legacy.arrays()[k], v)


# ---------------------------------------------------------------------------
# Hypothesis round-trip property (optional dependency, seeded tests above
# always run)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    def doc_batches():
        doc = st.lists(st.sampled_from(TOKENS[:12]), min_size=0, max_size=15)
        return st.lists(doc, min_size=1, max_size=25)

    @settings(max_examples=30, deadline=None)
    @given(
        batch=doc_batches(),
        flush_every=st.integers(1, 9),
        delete_at=st.integers(0, 24),
    )
    def test_hypothesis_columnar_roundtrip_parity(batch, flush_every, delete_at):
        """Random doc batches through the columnar pipeline produce segments
        bit-identical to the reference pipeline (arrays, postings,
        positions, live bitmaps), including mid-buffer deletes."""
        docs = [({"body": " ".join(toks)}, {"m": i % 5}) for i, toks in enumerate(batch)]
        deletes = [(min(delete_at, len(docs) - 1), TOKENS[0])]
        wc, _ = ingest("ram", None, docs, False, deletes, flush_every)
        wr, _ = ingest("ram", None, docs, True, deletes, flush_every)
        assert [s.name for s in wc.segments] == [s.name for s in wr.segments]
        for sc, sr in zip(wc.segments, wr.segments):
            assert_segments_identical(sc, sr, "hyp")
