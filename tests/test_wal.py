"""Durable write-ahead ingest buffer (``use_wal``): ack = durable,
commit = publish.

The contracts pinned here:

  * **Ack = durable** — a crash after N acked ``add_documents`` batches
    with NO commit recovers all N batches on the byte path; post-replay
    search results are bit-identical to a never-crashed writer across all
    six query families, unsharded and 2-shard sharded.
  * **One barrier per ack** — however many docs/fields/arrays a batch
    carries, the ack issues EXACTLY one durability barrier.
  * **Commit = publish** — with the WAL on, commit does not flush: the
    buffer tail stays log-covered, the root flip retires exactly the
    flushed span, and replay returns only the unretired tail.
  * **Bit-identical buffer replay** — the rebuilt ``ColumnarBuffer``
    columns, doc lens, doc values, and buffered deletes equal the
    pre-crash writer's, column for column.
  * **Rollback un-retires** — the sharded two-phase commit's rollback
    window restores the older WAL watermark, so a torn wave's acked
    batches replay instead of vanishing.
  * **Torn writes lose only the un-acked suffix** — a crash that tears the
    in-flight record (heap file truncated mid-batch) recovers exactly the
    fully-acked prefix (deterministic twin of the hypothesis test in
    ``test_wal_torn.py``).
  * **Graceful degradation** — ``use_wal`` on ram/fs directories is a
    no-op (``wal_enabled`` False), with classic commit semantics intact.
"""

import os

import numpy as np
import pytest

from repro.core import EXT_ID_FIELD, SearchEngine, ShardedEngine
from repro.core.search import (
    BooleanQuery,
    FacetQuery,
    PhraseQuery,
    RangeQuery,
    SortQuery,
    TermQuery,
)
from repro.data.corpus import CorpusConfig, synthetic_corpus

KINDS = ["ram", "fs-ssd", "byte-pmem"]
N_DOCS = 120
BATCH = 30


@pytest.fixture(scope="module")
def corpus():
    return list(synthetic_corpus(CorpusConfig(n_docs=N_DOCS, vocab=300, seed=7)))


def batches(corpus, size=BATCH):
    return [corpus[j : j + size] for j in range(0, len(corpus), size)]


def family_queries(corpus):
    """One query per family (term, boolean, phrase, range, sort, facet)."""
    from collections import Counter

    from repro.core import Analyzer

    an = Analyzer()
    c = Counter()
    for fields, _ in corpus:
        c.update(set(an.tokenize(fields["body"])))
    toks = [t for t, _ in c.most_common(4)]
    bigram = tuple(an.tokenize(corpus[0][0]["body"])[:2])
    return [
        TermQuery("body", toks[0]),
        BooleanQuery((TermQuery("body", toks[0]), TermQuery("body", toks[1])), "and"),
        BooleanQuery((TermQuery("body", toks[2]), TermQuery("body", toks[3])), "or"),
        PhraseQuery("body", bigram),
        RangeQuery("month", 3, 7),
        SortQuery(TermQuery("body", toks[0]), "timestamp"),
        FacetQuery(None, "month", 12),
    ]


def assert_same_results(queries, a, b, k=40):
    for q in queries:
        ta, tb = a.search(q, k=k), b.search(q, k=k)
        ctx = repr(q)
        assert ta.total_hits == tb.total_hits, ctx
        np.testing.assert_array_equal(ta.doc_ids, tb.doc_ids, err_msg=ctx)
        np.testing.assert_array_equal(ta.scores, tb.scores, err_msg=ctx)
        if isinstance(q, FacetQuery):
            np.testing.assert_array_equal(ta.facets, tb.facets, err_msg=ctx)


# ---------------------------------------------------------------------------
# capability / degradation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", KINDS)
def test_wal_capability_per_kind(tmp_path, kind, corpus):
    """Only the byte path can buy per-batch durability with one barrier;
    elsewhere ``use_wal`` degrades to a no-op and commit still flushes."""
    eng = SearchEngine(kind, str(tmp_path / "d"), use_wal=True)
    assert eng.wal_enabled == (kind == "byte-pmem")
    for b in batches(corpus):
        eng.add_documents(b)
    eng.commit()
    if not eng.wal_enabled:
        # classic commit: the buffer was flushed into durable segments
        assert eng.writer.buffered_docs == 0
    eng.reopen()
    assert eng.search(TermQuery("body", "wb"), k=5).total_hits >= 0  # serves


# ---------------------------------------------------------------------------
# ack = durable (the acceptance crash test), unsharded + sharded
# ---------------------------------------------------------------------------


def test_crash_after_acked_batches_no_commit(tmp_path, corpus):
    """N acked batches, zero commits, crash: all N replay, results match a
    never-crashed writer bit for bit across the six query families."""
    eng = SearchEngine("byte-pmem", str(tmp_path / "a"), use_wal=True)
    for b in batches(corpus):
        eng.add_documents(b)
    assert eng.writer.buffered_docs == N_DOCS
    rec = eng.crash_and_recover()
    assert rec.writer.buffered_docs == N_DOCS
    assert rec.writer.wal_stats["replayed"] == len(batches(corpus))

    ref = SearchEngine("byte-pmem", str(tmp_path / "ref"), use_wal=True)
    for b in batches(corpus):
        ref.add_documents(b)
    rec.reopen()
    ref.reopen()
    assert_same_results(family_queries(corpus), ref, rec)


def test_replayed_buffer_is_bit_identical(tmp_path, corpus):
    eng = SearchEngine("byte-pmem", str(tmp_path / "b"), use_wal=True)
    for b in batches(corpus):
        eng.add_documents(b)
    eng.delete("body", "wb")  # buffered-delete record rides the log too
    w = eng.writer
    before_cols = [c.copy() for c in w._buf.columns()]
    before_lens = list(w._buf_doc_lens)
    before_dv = {k: list(v) for k, v in w._buf_dv.items()}
    before_dels = list(w._buf_deletes)
    before_ram = w._ram_bytes

    rw = eng.crash_and_recover().writer
    for a, b_ in zip(before_cols, rw._buf.columns()):
        np.testing.assert_array_equal(a, b_)
    assert rw._buf_doc_lens == before_lens
    assert set(rw._buf_dv) == set(before_dv)
    for k in before_dv:
        np.testing.assert_array_equal(
            np.asarray(rw._buf_dv[k]), np.asarray(before_dv[k])
        )
    assert rw._buf_deletes == before_dels
    assert rw._ram_bytes == before_ram


def test_crash_with_commit_flush_and_tail(tmp_path, corpus):
    """Mixed timeline: batches → flush → commit (publish) → more batches →
    flush (no commit) → more batches → crash.  Recovery = committed
    segments + full log replay; results match the never-crashed engine."""
    def drive(eng):
        bs = batches(corpus)
        eng.add_documents(bs[0])
        eng.flush()
        eng.commit()
        eng.add_documents(bs[1])
        eng.flush()          # uncommitted segment (lost in the crash)
        eng.add_documents(bs[2])
        eng.add_documents(bs[3])
        return eng

    eng = drive(SearchEngine("byte-pmem", str(tmp_path / "c"), use_wal=True))
    ref = drive(SearchEngine("byte-pmem", str(tmp_path / "ref"), use_wal=True))
    rec = eng.crash_and_recover()
    rec.reopen()
    ref.reopen()
    assert_same_results(family_queries(corpus), ref, rec)


@pytest.mark.parametrize("kind", ["byte-pmem"])
def test_sharded_crash_after_acked_batches(tmp_path, kind, corpus):
    """The sharded acceptance half: per-shard WALs recover every acked
    batch past the manifest; fan-out results match a never-crashed sharded
    engine AND the unsharded reference, all families."""
    def drive(eng):
        bs = batches(corpus)
        eng.add_documents(bs[0])
        eng.add_documents(bs[1])
        eng.commit()  # manifest at 60 docs
        eng.add_documents(bs[2])
        eng.add_documents(bs[3])  # acked past the manifest
        return eng

    eng = drive(ShardedEngine(kind, str(tmp_path / "s"), n_shards=2,
                              use_wal=True, parallel=False))
    ref = drive(ShardedEngine(kind, str(tmp_path / "r"), n_shards=2,
                              use_wal=True, parallel=False))
    rec = eng.crash_and_recover()
    assert rec.writer.next_ext == N_DOCS
    rec.reopen()
    ref.reopen()
    assert_same_results(family_queries(corpus), ref, rec)

    # cross-check against the unsharded engine in external-id space
    uns = SearchEngine(kind, str(tmp_path / "u"), use_wal=True)
    for i, (fields, dv) in enumerate(corpus):
        uns.add({**fields}, {**dv, EXT_ID_FIELD: i})
    uns.flush()  # the ext-id map below reads segment doc-values
    uns.reopen()
    ext = np.concatenate(
        [np.asarray(s.doc_values[EXT_ID_FIELD]) for s in uns.manager.infos.segments]
    )
    for q in family_queries(corpus):
        ta, tb = uns.search(q, k=40), rec.search(q, k=40)
        assert ta.total_hits == tb.total_hits, repr(q)
        ids = ta.doc_ids if isinstance(q, FacetQuery) else ext[ta.doc_ids]
        np.testing.assert_array_equal(ids, tb.doc_ids, err_msg=repr(q))
        np.testing.assert_array_equal(ta.scores, tb.scores, err_msg=repr(q))


# ---------------------------------------------------------------------------
# barrier accounting + commit = publish
# ---------------------------------------------------------------------------


def test_ack_is_exactly_one_barrier_per_batch(tmp_path, corpus):
    eng = SearchEngine("byte-pmem", str(tmp_path / "d"), use_wal=True)
    heap = eng.directory.heap
    bs = batches(corpus)
    for i, b in enumerate(bs):
        before = heap.stats["barriers"]
        eng.add_documents(b)
        assert heap.stats["barriers"] == before + 1
    # a batch is ONE log record: one reserve + one store per ack
    assert eng.writer.wal_stats["appends"] == len(bs)
    before = heap.stats["barriers"]
    eng.commit()  # publish: one more barrier, no flush
    assert eng.directory.heap.stats["barriers"] == before + 1
    assert eng.writer.buffered_docs == N_DOCS


def test_commit_publishes_and_retires_flushed_span(tmp_path, corpus):
    eng = SearchEngine("byte-pmem", str(tmp_path / "e"), use_wal=True)
    bs = batches(corpus)
    eng.add_documents(bs[0])
    eng.add_documents(bs[1])
    eng.flush()
    eng.add_documents(bs[2])
    eng.commit()
    d = eng.directory
    # records 1-2 are inside the committed segment: retired; record 3 is
    # the live tail that must replay
    assert d.wal_retired() == 2
    replay = d.wal_replay()
    assert [m["seq"] for m, _ in replay] == [3]
    # after flush+commit the whole log is retired
    eng.flush()
    eng.commit()
    assert eng.directory.wal_replay() == []
    assert eng.writer.buffered_docs == 0


def test_rollback_unretires_wal_span(tmp_path, corpus):
    """The sharded two-phase window: a shard that committed (and retired)
    ahead of the manifest rolls back — the older root's watermark must
    bring the retired records back into replay."""
    eng = SearchEngine("byte-pmem", str(tmp_path / "f"), use_wal=True)
    bs = batches(corpus)
    eng.add_documents(bs[0])
    eng.flush()
    gen0 = eng.writer.commit(gc=False)   # retires record 1
    eng.add_documents(bs[1])
    eng.flush()
    eng.writer.commit(gc=False)          # retires record 2 (the torn wave)
    d = eng.directory
    assert d.wal_retired() == 2 and d.wal_replay() == []
    assert d.rollback_to(gen0)
    assert d.wal_retired() == 1
    assert [m["seq"] for m, _ in d.wal_replay()] == [2]
    # a writer opened on the rolled-back root replays batch 2 into the buffer
    rec = eng.crash_and_recover()
    assert rec.writer.buffered_docs == BATCH
    rec.reopen()
    assert rec.search(TermQuery("body", "wb"), k=N_DOCS).total_hits >= 0
    assert (
        rec.search(FacetQuery(None, "month", 12), k=12).total_hits == 2 * BATCH
    )


def test_compaction_carries_unretired_tail(tmp_path, corpus):
    """Heap compaction re-packs live segments into a fresh file — the
    unretired WAL tail must move with them (and keep replaying), while
    retired records are dropped as garbage."""
    eng = SearchEngine("byte-pmem", str(tmp_path / "g"), use_wal=True)
    eng.writer.merge_factor = 3
    bs = batches(corpus)
    for b in bs[:3]:
        eng.add_documents(b)
        eng.flush()
        eng.commit()
    eng.add_documents(bs[3])             # acked, never flushed
    # churn flush+commit cycles until gc compacts (merged-away segments
    # and retired records pile up as garbage)
    for i in range(12):
        eng.add_documents([corpus[0]])
        eng.flush()
        eng.commit()
    assert eng.directory.gc_info["compactions"] > 0
    rec = eng.crash_and_recover()
    rec.reopen()
    td = rec.search(FacetQuery(None, "month", 12), k=12)
    assert td.total_hits == N_DOCS + 12


# ---------------------------------------------------------------------------
# torn writes (deterministic twin of the hypothesis test)
# ---------------------------------------------------------------------------


def torn_crash(directory, frac=0.5):
    """Simulate power loss tearing the in-flight (un-acked) stores: the
    heap file keeps an arbitrary prefix of them — truncate at ``frac``
    between the committed watermark and the tail, zero-fill back."""
    heap = directory.heap
    lo, hi = heap.committed, max(heap.tail, heap.committed)
    cut = int(lo + frac * (hi - lo))
    cap = heap.capacity
    heap.close()
    with open(heap.path, "r+b") as f:
        f.truncate(cut)
        f.truncate(cap)


def test_torn_batch_recovers_acked_prefix(tmp_path, corpus):
    eng = SearchEngine("byte-pmem", str(tmp_path / "h"), use_wal=True)
    bs = batches(corpus)
    for b in bs[:3]:
        eng.add_documents(b)          # acked
    # an in-flight batch: stores issued, barrier never reached
    w = eng.writer
    d0, n0, p0 = len(w._buf_doc_lens), len(w._buf), w._buf.n_positions
    for fields, dv in bs[3]:
        w._append_document(fields, dv)
    th, dl, fr, po, ps = w._buf.columns()
    eng.directory._wal.append(
        {"kind": "batch", "base": d0, "dv_keys": []},
        {
            "term_hash": th[n0:], "doc_local": dl[n0:], "freq": fr[n0:],
            "pos_offset": po[n0:], "positions": ps[p0:],
            "doc_lens": np.asarray(w._buf_doc_lens[d0:], dtype=np.int64),
            "dv_key": np.empty(0, np.int32), "dv_doc": np.empty(0, np.int32),
            "dv_val": np.empty(0, np.float64),
        },
        durable=False,
    )
    path = eng.directory.path
    torn_crash(eng.directory, frac=0.6)
    # machine restart: everything reloads from disk
    rec = SearchEngine("byte-pmem", path, use_wal=True)
    assert rec.writer.buffered_docs == 3 * BATCH  # acked prefix, exactly
    rec.reopen()
    ref = SearchEngine("byte-pmem", str(tmp_path / "ref"), use_wal=True)
    for b in bs[:3]:
        ref.add_documents(b)
    ref.reopen()
    assert_same_results(family_queries(corpus), ref, rec)
