"""Dense-vector retrieval: the brute-force oracle pins every other path.

``VectorQuery`` scores a segment's ``_vec`` doc-values column (dot or
cosine) and the sequential oracle (``search_single``: jnp trailing-axis
reduce + heapq merge) defines the family bit-for-bit.  Everything else —
the vmapped batch executors, the fused jnp selection path, the Pallas
``vector_topk`` kernel, the sharded(2) fan-out, and the search-at-ack live
tail — must return bit-identical top-k ids AND scores on every directory
kind, including deleted docs, vectorless docs (zero rows: dot 0, cosine
guarded to 0), and multi-segment indexes.

The byte path's one-barrier commit invariant must survive vectors riding
the columnar buffer: a commit whose segments carry ``_vec`` columns still
pays exactly ONE durability barrier.
"""

import numpy as np
import pytest

from repro.core import SearchEngine
from repro.core.query import fused
from repro.core.search import TermQuery, VectorQuery
from repro.core.sharded import ShardedEngine
from repro.core.writer import VECTOR_FIELD

pytestmark = pytest.mark.vector

KINDS = ["ram", "fs-ssd", "byte-pmem"]
DIM = 24
N_DOCS = 260


def vec_corpus(n=N_DOCS, dim=DIM, seed=7):
    """Token soup + a ``_vec`` doc value on most docs (every 7th doc is
    vectorless: its zero row must score 0 under both metrics, not NaN)."""
    rng = np.random.default_rng(seed)
    docs = []
    for i in range(n):
        body = " ".join(f"w{rng.integers(0, 40)}" for _ in range(12))
        dv = {"month": float(i % 12)}
        if i % 7 != 3:
            dv[VECTOR_FIELD] = rng.standard_normal(dim).astype(np.float32)
        docs.append(({"body": body}, dv))
    return docs


def queries(dim=DIM, seed=11, per_metric=3):
    rng = np.random.default_rng(seed)
    qs = []
    for metric in ("dot", "cosine"):
        for _ in range(per_metric):
            v = tuple(float(x) for x in rng.standard_normal(dim))
            qs.append(VectorQuery(v, metric=metric))
    return qs


def build(kind, path, use_pallas=False, n_shards=0, backend=None):
    p = str(path) if path else None
    if n_shards:
        kw = dict(n_shards=n_shards, use_pallas=use_pallas)
        if backend is None:
            kw["parallel"] = False
        else:
            kw["backend"] = backend
        eng = ShardedEngine(kind, path=p, **kw)
    else:
        eng = SearchEngine(kind, path=p, use_pallas=use_pallas)
    for i, (fields, dv) in enumerate(vec_corpus()):
        eng.add(fields, dv)
        if (i + 1) % 90 == 0:
            eng.flush()
    eng.delete("body", "w5")
    eng.reopen()
    return eng


def assert_identical(a, b, ctx=""):
    assert a.total_hits == b.total_hits, ctx
    np.testing.assert_array_equal(a.doc_ids, b.doc_ids, err_msg=ctx)
    np.testing.assert_array_equal(a.scores, b.scores, err_msg=ctx)


@pytest.mark.parametrize("kind", KINDS)
def test_batch_matches_single_oracle(kind, tmp_path):
    eng = build(kind, None if kind == "ram" else tmp_path / "e")
    qs = queries()
    got = eng.search_batch(qs, k=10)
    for q, g in zip(qs, got):
        assert_identical(g, eng.searcher.search_single(q, k=10), repr(q))


@pytest.mark.parametrize("kind", KINDS)
def test_fused_jnp_matches_oracle(kind, tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_FUSED_KERNEL", raising=False)
    ref = build(kind, None if kind == "ram" else tmp_path / "ref")
    fe = build(kind, None if kind == "ram" else tmp_path / "fe", True)
    qs = queries()
    for q, g, v in zip(qs, fe.search_batch(qs, k=10), ref.search_batch(qs, k=10)):
        assert_identical(g, v, repr(q))


@pytest.mark.parametrize("kind", KINDS)
def test_fused_kernel_matches_oracle(kind, tmp_path, monkeypatch):
    """Force the Pallas vector_topk kernel (interpret mode on CPU)."""
    monkeypatch.setenv("REPRO_FUSED_KERNEL", "1")
    assert fused.kernel_enabled(10)
    ref = build(kind, None if kind == "ram" else tmp_path / "ref")
    fe = build(kind, None if kind == "ram" else tmp_path / "fe", True)
    qs = queries()
    for q, g, v in zip(qs, fe.search_batch(qs, k=10), ref.search_batch(qs, k=10)):
        assert_identical(g, v, repr(q))


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("use_pallas", [False, True])
def test_sharded_matches_unsharded(kind, use_pallas, tmp_path):
    """2-shard fan-out == single index: the fixed similarity is
    shard-independent, so the cross-shard lexsort merge reproduces the
    unsharded ranking bit-for-bit (external id == add order here)."""
    ref = build(kind, None if kind == "ram" else tmp_path / "ref", use_pallas)
    sh = build(
        kind, None if kind == "ram" else tmp_path / "sh", use_pallas, n_shards=2
    )
    qs = queries()
    for q, a, b in zip(qs, ref.search_batch(qs, k=10), sh.search_batch(qs, k=10)):
        assert_identical(a, b, repr(q))


@pytest.mark.parametrize("backend", ["threads", "processes"])
def test_sharded_backends_match_unsharded(backend, tmp_path):
    ref = build("ram", None)
    sh = build("ram", None, n_shards=2, backend=backend)
    try:
        qs = queries()
        for q, a, b in zip(
            qs, ref.search_batch(qs, k=10), sh.search_batch(qs, k=10)
        ):
            assert_identical(a, b, repr(q))
    finally:
        sh.close()


def test_live_tail_matches_flush(tmp_path):
    """Search-at-ack: vector results over (committed ∪ buffered tail) are
    bit-identical to flushing the tail first."""
    docs = vec_corpus()
    eng = SearchEngine("ram")
    for fields, dv in docs[:180]:
        eng.add(fields, dv)
    eng.flush()
    eng.commit()
    for fields, dv in docs[180:]:
        eng.add(fields, dv)
    eng.reopen()
    qs = queries()
    live_b = eng.search_batch(qs, k=12)
    live_s = [eng.searcher.search_single(q, k=12) for q in qs]
    eng.flush()
    eng.reopen()
    flushed = eng.search_batch(qs, k=12)
    for q, lb, ls, fl in zip(qs, live_b, live_s, flushed):
        assert_identical(lb, fl, f"live batch vs flushed: {q!r}")
        assert_identical(ls, fl, f"live single vs flushed: {q!r}")


def test_wal_replay_matches_uncrashed(tmp_path):
    """Acked vector batches survive a crash: replay == never-crashed."""
    docs = vec_corpus(120)
    eng = SearchEngine("byte-pmem", str(tmp_path / "d"), use_wal=True)
    for i in range(0, len(docs), 30):
        eng.add_documents(docs[i : i + 30])
    rec = eng.crash_and_recover()
    rec.reopen()
    ref = SearchEngine("ram")
    for i in range(0, len(docs), 30):
        ref.add_documents(docs[i : i + 30])
    ref.reopen()
    qs = queries()
    for q, a, b in zip(qs, ref.search_batch(qs, k=10), rec.search_batch(qs, k=10)):
        assert_identical(a, b, repr(q))


def test_byte_commit_with_vectors_is_one_barrier(tmp_path):
    """The write-combining invariant survives the vector column: commit =
    publish, exactly ONE durability barrier — segment bytes (postings AND
    ``_vec`` rows) were stored long before, the barrier only fences the
    root flip."""
    eng = SearchEngine("byte-pmem", str(tmp_path / "d"))
    docs = vec_corpus(150)
    for fields, dv in docs[:70]:
        eng.add(fields, dv)
    eng.flush()
    for fields, dv in docs[70:]:
        eng.add(fields, dv)
    eng.flush()  # two segments, both carrying _vec columns
    b0 = eng.directory.heap.stats["barriers"]
    eng.commit()
    assert eng.directory.heap.stats["barriers"] - b0 == 1
    eng.reopen()
    got = eng.search(queries(per_metric=1)[0], k=5)
    assert got.total_hits > 0


def test_merge_preserves_vector_scores(tmp_path):
    """Tiered merge with deletes: the merged ``_vec`` column is a live-row
    compaction (bit-identical to the reference merge, rows following their
    doc) and vector ranking is unchanged modulo the doc-id remap."""
    from repro.core.search import Searcher
    from repro.core.segment import merge_segments, merge_segments_reference

    eng = SearchEngine("ram")
    for i, (fields, dv) in enumerate(vec_corpus()):
        dv["docno"] = float(i)
        eng.add(fields, dv)
        if (i + 1) % 60 == 0:
            eng.flush()
    eng.flush()  # no live tail: the merged Searcher must cover everything
    eng.delete("body", "w7")
    eng.reopen()
    segs = list(eng.writer.segments)
    merged = merge_segments("merged-all", 0, segs)
    ref = merge_segments_reference("merged-all", 0, segs)
    np.testing.assert_array_equal(
        merged.doc_values[VECTOR_FIELD], ref.doc_values[VECTOR_FIELD]
    )
    expect = np.concatenate([s.doc_values[VECTOR_FIELD][s.live] for s in segs])
    np.testing.assert_array_equal(merged.doc_values[VECTOR_FIELD], expect)
    qs = queries()
    before = eng.search_batch(qs, k=10)
    ms = Searcher([merged])
    for q, a in zip(qs, before):
        b = ms.search_single(q, k=10)
        assert a.total_hits == b.total_hits, repr(q)
        np.testing.assert_array_equal(a.scores, b.scores, err_msg=repr(q))
        # identity survives the remap: same docs, by their docno column
        docno_a = np.concatenate(
            [s.doc_values["docno"] for s in segs]
        )[np.asarray(a.doc_ids)]
        docno_b = merged.doc_values["docno"][np.asarray(b.doc_ids)]
        np.testing.assert_array_equal(docno_a, docno_b, err_msg=repr(q))


def test_vectorless_index_vector_query_is_empty():
    """No segment carries ``_vec``: the family returns 0 hits, not NaN."""
    eng = SearchEngine("ram")
    for fields, dv in vec_corpus(60):
        dv.pop(VECTOR_FIELD, None)
        eng.add(fields, dv)
    eng.reopen()
    q = VectorQuery(tuple(1.0 for _ in range(DIM)))
    for td in (eng.search(q, k=5), eng.search_batch([q], k=5)[0]):
        assert td.total_hits == 0
        assert len(td.doc_ids) == 0


def test_dim_mismatch_rejected():
    eng = SearchEngine("ram")
    eng.add({"body": "w1"}, {VECTOR_FIELD: np.ones(8, np.float32)})
    with pytest.raises(ValueError):
        eng.add({"body": "w2"}, {VECTOR_FIELD: np.ones(9, np.float32)})
