"""Sharded indexing + fan-out search: parity, crash atomicity, NRT isolation.

Three contracts pinned here:

  1. **Bit-parity** — a sharded index with a fixed router returns results
     *identical* to one unsharded index over the same corpus (external-id
     space; cross-shard BM25 statistics), for every query family and every
     directory kind.  ``shards=1`` is the degenerate case whose doc ids
     coincide with the unsharded positional ids outright.
  2. **Cross-shard commit atomicity** — a crash between per-shard commits
     recovers every shard to the cross-shard manifest's single point in
     time (the early committers roll back).
  3. **Per-shard NRT isolation** — reopening one shard swaps only that
     shard's searcher; the other shards' point-in-time views and
     device-resident caches are untouched.
"""

from collections import Counter

import numpy as np
import pytest

from repro.core import (
    Analyzer,
    EXT_ID_FIELD,
    HashFieldRouter,
    SearchEngine,
    ShardedEngine,
)
from repro.core.search import (
    BooleanQuery,
    FacetQuery,
    PhraseQuery,
    RangeQuery,
    SortQuery,
    TermQuery,
)
from repro.data.corpus import CorpusConfig, synthetic_corpus

N_DOCS = 240
FLUSH_EVERY = 60
KINDS = ["ram", "fs-ssd", "byte-pmem"]


@pytest.fixture(scope="module")
def corpus():
    return list(synthetic_corpus(CorpusConfig(n_docs=N_DOCS, vocab=400, seed=7)))


def common_tokens(corpus, n):
    c = Counter()
    an = Analyzer()
    for fields, _ in corpus:
        c.update(set(an.tokenize(fields["body"])))
    return [t for t, _ in c.most_common(n)]


def all_family_batch(corpus):
    toks = common_tokens(corpus, 6)
    an = Analyzer()
    bigram = tuple(an.tokenize(corpus[0][0]["body"])[:2])
    return [
        TermQuery("body", toks[0]),
        TermQuery("body", toks[4]),
        BooleanQuery((TermQuery("body", toks[0]), TermQuery("body", toks[1])), "and"),
        BooleanQuery((TermQuery("body", toks[2]), TermQuery("body", toks[3])), "or"),
        PhraseQuery("body", bigram),
        RangeQuery("month", 3, 7),
        SortQuery(TermQuery("body", toks[0]), "timestamp"),
        FacetQuery(None, "month", 12),
        FacetQuery(TermQuery("body", toks[1]), "month", 12),
    ]


def build_unsharded(kind, path, corpus):
    """Reference engine; the external-id column is injected so results can
    be compared in external-id space (what the sharded engine reports)."""
    eng = SearchEngine(kind, path=str(path) if path else None)
    for i, (fields, dv) in enumerate(corpus):
        eng.add(fields, {**dv, EXT_ID_FIELD: i})
        if (i + 1) % FLUSH_EVERY == 0:
            eng.flush()
    eng.commit()
    eng.reopen()
    return eng


def build_sharded(kind, path, corpus, n_shards, router=None, backend=None,
                  use_wal=False):
    eng = ShardedEngine(
        kind, path=str(path) if path else None, n_shards=n_shards,
        router=router, backend=backend, use_wal=use_wal,
    )
    for j in range(0, len(corpus), FLUSH_EVERY):
        eng.add_documents(corpus[j : j + FLUSH_EVERY])
        eng.flush()
    eng.commit()
    eng.reopen()
    return eng


def ext_map(eng: SearchEngine) -> np.ndarray:
    return np.concatenate(
        [np.asarray(s.doc_values[EXT_ID_FIELD]) for s in eng.manager.infos.segments]
    )


def assert_results_identical(queries, ref, ref_ext, sharded_results):
    for q, ta, tb in zip(queries, ref, sharded_results):
        ctx = repr(q)
        assert ta.total_hits == tb.total_hits, ctx
        ids_a = ta.doc_ids if isinstance(q, FacetQuery) else ref_ext[ta.doc_ids]
        np.testing.assert_array_equal(ids_a, tb.doc_ids, err_msg=ctx)
        np.testing.assert_array_equal(ta.scores, tb.scores, err_msg=ctx)
        if isinstance(q, FacetQuery):
            np.testing.assert_array_equal(ta.facets, tb.facets, err_msg=ctx)


# ---------------------------------------------------------------------------
# 1. bit-parity: sharded == unsharded, all families x all kinds
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["serial", "threads", "processes"])
@pytest.mark.parametrize("kind", KINDS)
def test_sharded_parity_all_families(kind, backend, tmp_path, corpus):
    ref = build_unsharded(kind, tmp_path / "ref" if kind != "ram" else None, corpus)
    sh = build_sharded(
        kind, tmp_path / "sh" if kind != "ram" else None, corpus, 3,
        backend=backend,
    )
    try:
        queries = all_family_batch(corpus)
        a = ref.search_batch(queries, k=10)
        b = sh.search_batch(queries, k=10)
        assert_results_identical(queries, a, ext_map(ref), b)
        # single-query path rides the same fan-out
        td = sh.search(queries[0], k=10)
        np.testing.assert_array_equal(td.doc_ids, b[0].doc_ids)
    finally:
        sh.close()


def test_sharded_parity_survives_merges(corpus):
    """Aggressive tiered merging (merge_factor=2 cascades on every commit)
    must not disturb parity: the external-id mapping depends on base_doc
    contiguity and doc-values row order surviving the merge remap, which is
    exactly what this pins.  Bitmap-only deletes ride along afterwards
    (no rewrite: df and merge timing stay identical on both sides)."""
    ref = SearchEngine("ram")
    ref.writer.merge_factor = 2
    sh = ShardedEngine("ram", n_shards=3)
    for w in sh.writer.writers:
        w.merge_factor = 2
    try:
        for j in range(0, len(corpus), 30):  # many small flushes -> cascades
            for i, (fields, dv) in enumerate(corpus[j : j + 30], start=j):
                ref.add(fields, {**dv, EXT_ID_FIELD: i})
            sh.add_documents(corpus[j : j + 30])
            ref.commit()
            sh.commit()
        ref.reopen()
        sh.reopen()
        assert all(len(w.infos) < 4 for w in sh.writer.writers)  # merges ran
        queries = all_family_batch(corpus)
        assert_results_identical(
            queries, ref.search_batch(queries, k=10), ext_map(ref),
            sh.search_batch(queries, k=10),
        )
        # deletes after the merging settled: bitmap clones only (no flush,
        # no rewrite), applied to merged segments on both sides
        tok = common_tokens(corpus, 2)[1]
        assert ref.delete("body", tok) == sh.delete("body", tok)
        ref.reopen()
        sh.reopen()
        assert_results_identical(
            queries, ref.search_batch(queries, k=10), ext_map(ref),
            sh.search_batch(queries, k=10),
        )
    finally:
        sh.close()


def test_shards1_degenerate_case_identical_doc_ids(corpus):
    """One shard, identity routing: even the *positional* doc ids coincide
    with the unsharded engine (external id == global id)."""
    ref = build_unsharded("ram", None, corpus)
    sh = build_sharded("ram", None, corpus, 1)
    try:
        for q in all_family_batch(corpus):
            a = ref.search(q, k=10)
            b = sh.search(q, k=10)
            assert a.total_hits == b.total_hits, q
            np.testing.assert_array_equal(a.doc_ids, b.doc_ids, err_msg=repr(q))
            np.testing.assert_array_equal(a.scores, b.scores, err_msg=repr(q))
    finally:
        sh.close()


def test_field_router_parity_and_colocation(corpus):
    """A field router changes placement, not results; all docs sharing the
    routing key land on one shard."""
    ref = build_unsharded("ram", None, corpus)
    router = HashFieldRouter(3, "title")
    sh = build_sharded("ram", None, corpus, 3, router=router)
    try:
        queries = all_family_batch(corpus)
        assert_results_identical(
            queries, ref.search_batch(queries, k=10), ext_map(ref),
            sh.search_batch(queries, k=10),
        )
        # colocation: every document's shard is the router's verdict
        for sid, s in enumerate(sh.manager.searcher.searchers):
            for ext in s.ext_ids:
                fields, dv = corpus[int(ext)]
                assert router.route(fields, dv, int(ext)) == sid
    finally:
        sh.close()


def test_sharded_delete_fans_out(corpus):
    """delete_by_term kills matching docs on every shard; parity with the
    unsharded engine holds when merges don't drop docs underneath."""
    ref = build_unsharded("ram", None, corpus)
    sh = build_sharded("ram", None, corpus, 3)
    try:
        tok = common_tokens(corpus, 1)[0]
        n_ref = ref.delete("body", tok)
        n_sh = sh.delete("body", tok)
        assert n_ref == n_sh
        ref.reopen()
        sh.reopen()
        assert sh.search(TermQuery("body", tok), k=10).total_hits == 0
        other = common_tokens(corpus, 5)[-1]
        q = TermQuery("body", other)
        a, b = ref.search(q, k=10), sh.search(q, k=10)
        assert a.total_hits == b.total_hits
        np.testing.assert_array_equal(ext_map(ref)[a.doc_ids], b.doc_ids)
        np.testing.assert_array_equal(a.scores, b.scores)
    finally:
        sh.close()


# ---------------------------------------------------------------------------
# 2. cross-shard commit atomicity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["fs-ssd", "byte-pmem"])
def test_crash_between_shard_commits_recovers_one_point_in_time(
    kind, tmp_path, corpus
):
    eng = ShardedEngine(kind, path=str(tmp_path / "idx"), n_shards=3)
    eng.add_documents(corpus[:120])
    eng.commit()
    eng.reopen()
    q = TermQuery("body", common_tokens(corpus, 1)[0])
    before = eng.search(q, k=20)

    # second wave reaches only shard 0 before the power fails: shard 0 is
    # durable one generation ahead, shards 1-2 and the manifest are not
    eng.add_documents(corpus[120:])
    eng.flush()
    eng.writer.writers[0].commit({"epoch": 99}, gc=False)
    rec = eng.crash_and_recover()
    try:
        assert rec.writer.next_ext == 120
        assert sum(w.infos.total_docs for w in rec.writer.writers) == 120
        # per-shard latest commits all match the manifest's generations
        manifest = rec.shards.read_manifest()
        for d, gen in zip(rec.shards.dirs, manifest["gens"]):
            assert d.latest_commit()[0] == gen
        rec.reopen()
        after = rec.search(q, k=20)
        assert after.total_hits == before.total_hits
        np.testing.assert_array_equal(after.doc_ids, before.doc_ids)
        np.testing.assert_array_equal(after.scores, before.scores)
        # external ids continue from the recovered watermark
        assert rec.add(*corpus[120]) == 120
    finally:
        rec.close()


@pytest.mark.parametrize("kind", ["fs-ssd", "byte-pmem"])
def test_torn_wave_deletes_do_not_leak_into_rollback(kind, tmp_path, corpus):
    """A delete durably committed by ONE shard ahead of the manifest must
    roll back with the wave: the recovered point in time predates it.
    (On the file path this means pruning the wave's fsynced .liv
    generations, not just its segments_N manifest.)"""
    eng = ShardedEngine(kind, path=str(tmp_path / "idx"), n_shards=2)
    eng.add_documents(corpus[:120])
    eng.commit()
    eng.reopen()
    # a LOW-df token: the delete must stay under the deletes-pct rewrite
    # threshold so the segments survive and only .liv generations change
    an = Analyzer()
    counts = Counter()
    for fields, _ in corpus[:120]:
        counts.update(set(an.tokenize(fields["body"])))
    tok = next(t for t, c in counts.most_common() if c <= 4)
    q = TermQuery("body", tok)
    before = eng.search(q, k=20)
    assert before.total_hits > 0

    # the torn wave: a delete lands (below the rewrite threshold, so the
    # segments stay and only new .liv generations are written), shard 0
    # commits it durably, then power fails before the manifest
    eng.delete("body", tok)
    eng.writer.writers[0].commit({}, gc=False)
    rec = eng.crash_and_recover()
    try:
        rec.reopen()
        after = rec.search(q, k=20)
        assert after.total_hits == before.total_hits
        np.testing.assert_array_equal(after.doc_ids, before.doc_ids)
        np.testing.assert_array_equal(after.scores, before.scores)
    finally:
        rec.close()


@pytest.mark.parametrize("kind", ["fs-ssd", "byte-pmem"])
def test_crash_after_manifest_recovers_new_wave(kind, tmp_path, corpus):
    """Once the manifest is durable the whole wave survives, even if the
    crash preempts the deferred GC."""
    eng = ShardedEngine(kind, path=str(tmp_path / "idx"), n_shards=2)
    eng.add_documents(corpus[:80])
    eng.commit()
    eng.add_documents(corpus[80:160])
    eng.commit()  # wave 2 fully durable (manifest written, gc ran)
    rec = eng.crash_and_recover()
    try:
        assert rec.writer.next_ext == 160
        assert sum(w.infos.total_docs for w in rec.writer.writers) == 160
    finally:
        rec.close()


def test_crash_before_first_manifest_recovers_empty(tmp_path, corpus):
    """A torn FIRST wave (some shards committed, no manifest yet) recovers
    to the empty index, not to half a commit."""
    eng = ShardedEngine("fs-ssd", path=str(tmp_path / "idx"), n_shards=2)
    eng.add_documents(corpus[:40])
    eng.flush()
    eng.writer.writers[0].commit({}, gc=False)  # crash before the manifest
    rec = eng.crash_and_recover()
    try:
        assert rec.writer.next_ext == 0
        assert sum(w.infos.total_docs for w in rec.writer.writers) == 0
    finally:
        rec.close()


def test_torn_wave_rollback_without_crash_restores_live_bitmaps(corpus):
    """Recovery over a still-live ShardSet (no power loss — e.g. the
    coordinator died mid-wave): a delete one shard committed ahead of the
    manifest rolls back on EVERY kind, including ram, where the bitmaps
    live in process memory rather than .liv files."""
    from repro.core import ShardedWriter

    eng = build_sharded("ram", None, corpus[:120], 2)
    tok = common_tokens(corpus[:120], 1)[0]
    alive = eng.search(TermQuery("body", tok), k=20).total_hits
    assert alive > 0
    eng.delete("body", tok)
    eng.writer.writers[0].commit({}, gc=False)  # wave torn after shard 0
    eng.close()

    w2 = ShardedWriter(eng.shards)  # reopen WITHOUT crash
    from repro.core import ShardedSearcherManager

    mgr = ShardedSearcherManager(w2)
    td = mgr.searcher.search(TermQuery("body", tok), k=20)
    assert td.total_hits == alive  # the never-manifested delete rolled back
    w2.close()


def test_ram_crash_loses_everything_consistently(corpus):
    eng = build_sharded("ram", None, corpus, 3)
    rec = eng.crash_and_recover()
    try:
        assert rec.writer.next_ext == 0
        assert sum(w.infos.total_docs for w in rec.writer.writers) == 0
    finally:
        rec.close()


# ---------------------------------------------------------------------------
# 3. per-shard NRT reopen isolation
# ---------------------------------------------------------------------------


def test_per_shard_reopen_leaves_other_searchers_untouched(corpus):
    # field router: documents sharing a title co-locate, so new docs can be
    # steered at ONE shard through the public API
    router = HashFieldRouter(3, "title")
    sh = build_sharded("ram", None, corpus, 3, router=router)
    try:
        searchers = [m.searcher for m in sh.manager.managers]
        uploads = [c.stats.segment_uploads for c in sh.device_caches]

        target, fresh = None, []
        for fields, dv in corpus[:9]:
            sid = router.route(fields, dv, 0)
            if target is None:
                target = sid
            if sid == target:
                fresh.append((fields, dv))
        sh.add_documents(fresh)
        assert sh.writer.writers[target].buffered_docs == len(fresh) > 0

        sh.reopen(shard=target)
        now = [m.searcher for m in sh.manager.managers]
        for sid in range(3):
            if sid == target:
                assert now[sid] is not searchers[sid]
            else:
                assert now[sid] is searchers[sid]  # untouched point in time
                assert (
                    sh.device_caches[sid].stats.segment_uploads == uploads[sid]
                )
    finally:
        sh.close()


def test_retained_fanout_searcher_is_point_in_time(corpus):
    """A handed-out ShardedSearcher keeps bit-identical results while the
    writer ingests and shards reopen underneath it (the Searcher contract,
    lifted to the fan-out view: stats bindings are per-snapshot, never
    mutated in place)."""
    sh = build_sharded("ram", None, corpus[:180], 3)
    try:
        old = sh.searcher
        queries = all_family_batch(corpus[:180])
        before = old.search_batch(queries, k=10)
        # grow and refresh the index: new docs, per-shard + full reopens
        sh.add_documents(corpus[180:])
        sh.reopen(shard=0)
        sh.reopen()
        new = sh.searcher.search_batch(queries, k=10)
        after = old.search_batch(queries, k=10)  # the OLD view, re-asked
        for q, ta, tb in zip(queries, before, after):
            assert ta.total_hits == tb.total_hits, q
            np.testing.assert_array_equal(ta.doc_ids, tb.doc_ids, err_msg=repr(q))
            np.testing.assert_array_equal(ta.scores, tb.scores, err_msg=repr(q))
        # and the refreshed view actually moved (sanity: not vacuous)
        assert any(
            a.total_hits != b.total_hits for a, b in zip(before, new)
        )
    finally:
        sh.close()


def test_sharded_stats_aggregate(corpus):
    sh = build_sharded("ram", None, corpus, 3)
    try:
        st = sh.stats()
        assert st["shards"] == 3
        assert st["docs"] == N_DOCS
        assert len(st["per_shard"]) == 3
        assert st["segments"] == sum(s["segments"] for s in st["per_shard"])
        assert len(st["busy_s"]) == 3 and all(b > 0 for b in st["busy_s"])
    finally:
        sh.close()


# ---------------------------------------------------------------------------
# 4. processes backend: worker-crash fault injection (SIGKILL)
# ---------------------------------------------------------------------------
#
# A shard worker is SIGKILLed at the two dangerous points of the ingest
# lifecycle: mid-``add_documents`` (before any buffer/WAL mutation) and
# between phase 1 and phase 2 of the cross-shard commit (its shard durably
# one generation ahead of the manifest).  Recovery — a fresh coordinator
# over the same durable bytes — must roll every shard back to the
# manifest's single point in time, un-retire the WAL spans the torn wave
# retired, and replay the acked prefix bit-identically.


def _drive_acked(eng, corpus):
    """60-doc acked batches: two waves committed, two acked past the
    manifest (the WAL-held tail recovery must replay)."""
    eng.add_documents(corpus[:60])
    eng.add_documents(corpus[60:120])
    eng.commit()  # manifest at 120 docs
    eng.add_documents(corpus[120:180])
    eng.add_documents(corpus[180:240])  # acked, never committed
    return eng


def _assert_bit_identical(corpus, ref, rec):
    """Flush+reopen both sides, then compare every query family."""
    ref.reopen()
    rec.reopen()
    queries = all_family_batch(corpus)
    a = ref.search_batch(queries, k=20)
    b = rec.search_batch(queries, k=20)
    for q, ta, tb in zip(queries, a, b):
        assert ta.total_hits == tb.total_hits, repr(q)
        np.testing.assert_array_equal(ta.doc_ids, tb.doc_ids, err_msg=repr(q))
        np.testing.assert_array_equal(ta.scores, tb.scores, err_msg=repr(q))


@pytest.mark.parametrize("backend", ["processes"])
@pytest.mark.parametrize("kind", ["byte-pmem"])
def test_worker_sigkill_mid_add_recovers_acked_prefix(
    kind, backend, tmp_path, corpus
):
    """SIGKILL one shard's worker at the moment an add arrives (before any
    mutation): the un-acked batch is lost — everything acked before it
    replays bit-identically.  The batch is a single document routed AT the
    killed shard, so no sibling shard holds a durably-logged slice of it
    (per-shard WALs ack independently; a multi-shard batch would leave the
    survivor's half durable)."""
    eng = _drive_acked(
        ShardedEngine(kind, str(tmp_path / "s"), n_shards=2,
                      backend=backend, use_wal=True),
        corpus,
    )
    eng.writer.inject_fault(0, "kill_before_add")
    # next ext id is 240 -> HashIdRouter sends it to shard 240 % 2 == 0
    with pytest.raises(RuntimeError, match="worker died"):
        eng.add(*corpus[0])
    eng.close()  # teardown with a dead worker must reap the survivor too

    rec = ShardedEngine(kind, str(tmp_path / "s"), n_shards=2,
                        backend=backend, use_wal=True)
    ref = _drive_acked(
        ShardedEngine(kind, str(tmp_path / "r"), n_shards=2,
                      backend=backend, use_wal=True),
        corpus,
    )
    try:
        assert rec.writer.next_ext == N_DOCS  # the killed doc never acked
        _assert_bit_identical(corpus, ref, rec)
    finally:
        rec.close()
        ref.close()


@pytest.mark.parametrize("backend", ["processes"])
@pytest.mark.parametrize("kind", ["byte-pmem"])
def test_worker_sigkill_between_commit_phases_rolls_back_wave(
    kind, backend, tmp_path, corpus
):
    """SIGKILL one worker after its phase-1 commit is durable but before it
    reports: the coordinator never writes the manifest, so the whole wave
    is torn.  Recovery rolls EVERY shard back to the previous manifest
    (shards that committed are one generation ahead), un-retires the WAL
    spans that commit retired, and replays the acked tail — bit-identical
    to a reference that never attempted the torn commit."""
    eng = _drive_acked(
        ShardedEngine(kind, str(tmp_path / "s"), n_shards=2,
                      backend=backend, use_wal=True),
        corpus,
    )
    eng.writer.inject_fault(0, "kill_after_commit")
    with pytest.raises(RuntimeError, match="worker died"):
        eng.commit()  # phase 1 runs on both shards; the manifest never lands
    eng.close()

    rec = ShardedEngine(kind, str(tmp_path / "s"), n_shards=2,
                        backend=backend, use_wal=True)
    ref = _drive_acked(
        ShardedEngine(kind, str(tmp_path / "r"), n_shards=2,
                      backend=backend, use_wal=True),
        corpus,
    )
    try:
        assert rec.writer.epoch == 0  # the torn epoch-1 wave was rolled back
        assert rec.writer.next_ext == N_DOCS  # acked tail replayed
        _assert_bit_identical(corpus, ref, rec)
    finally:
        rec.close()
        ref.close()
