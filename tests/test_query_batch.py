"""Batched query execution: parity with the sequential oracle + device cache.

The batched planner/executor path (``Searcher.search_batch``) must return
bit-identical ``TopDocs`` to the surviving per-query oracle path
(``Searcher.search_single``) for every query family and every directory
kind, and the engine-owned ``SegmentDeviceCache`` must not re-upload
unchanged segments across NRT reopens.
"""

import numpy as np
import pytest

from repro.core import SearchEngine, SegmentDeviceCache
from repro.core.query.plan import family_key, plan_batch
from repro.core.search import (
    BooleanQuery,
    FacetQuery,
    PhraseQuery,
    RangeQuery,
    SortQuery,
    TermQuery,
)
from repro.data.corpus import CorpusConfig, synthetic_corpus, _word

N_DOCS = 400


def _build(kind: str, path=None) -> SearchEngine:
    eng = SearchEngine(kind, path=str(path) if path else None)
    for i, (fields, dv) in enumerate(
        synthetic_corpus(CorpusConfig(n_docs=N_DOCS, vocab=500, seed=11))
    ):
        eng.add(fields, dv)
        if (i + 1) % 90 == 0:
            eng.flush()  # several segments
    eng.delete("body", _word(120))  # exercise the live bitmap
    eng.reopen()
    return eng


def _mixed_batch():
    highs = [_word(i) for i in (1, 2, 3)]
    meds = [_word(i) for i in (20, 40, 60)]
    return (
        [TermQuery("body", t) for t in highs + meds]
        + [
            BooleanQuery((TermQuery("body", a), TermQuery("body", b)), m)
            for m in ("and", "or")
            for a, b in [(highs[0], highs[1]), (highs[2], meds[0])]
        ]
        + [PhraseQuery("body", (highs[0], highs[1]))]
        + [SortQuery(TermQuery("body", t), "timestamp") for t in highs]
        + [RangeQuery("month", 2, 9), RangeQuery("month", 0, 5)]
        + [
            FacetQuery(None, "month", 12),
            FacetQuery(TermQuery("body", highs[0]), "month", 12),
        ]
    )


def _assert_topdocs_identical(a, b, ctx=""):
    assert a.total_hits == b.total_hits, ctx
    np.testing.assert_array_equal(a.doc_ids, b.doc_ids, err_msg=ctx)
    # bit-identical scores: the batched executors are vmap of the same cores
    np.testing.assert_array_equal(a.scores, b.scores, err_msg=ctx)
    assert (a.facets is None) == (b.facets is None), ctx
    if a.facets is not None:
        np.testing.assert_array_equal(a.facets, b.facets, err_msg=ctx)


@pytest.mark.parametrize("kind", ["ram", "fs-ssd", "byte-pmem"])
def test_search_batch_parity_all_families(kind, tmp_path):
    eng = _build(kind, tmp_path / kind if kind != "ram" else None)
    queries = _mixed_batch()
    batch = eng.search_batch(queries, k=10)
    assert len(batch) == len(queries)
    s = eng.searcher
    for q, td in zip(queries, batch):
        _assert_topdocs_identical(td, s.search_single(q, k=10), ctx=repr(q))


def test_search_is_batch_of_one():
    eng = _build("ram")
    for q in _mixed_batch()[:6]:
        _assert_topdocs_identical(
            eng.search(q, k=10), eng.search_batch([q], k=10)[0], ctx=repr(q)
        )


def test_batch_parity_with_deletes_and_k_edge():
    """k larger than every postings list + deletions applied mid-stream."""
    eng = _build("ram")
    eng.delete("body", _word(1))
    eng.reopen()
    queries = [TermQuery("body", _word(i)) for i in (1, 2, 3, 999983)]
    batch = eng.search_batch(queries, k=N_DOCS)
    s = eng.searcher
    for q, td in zip(queries, batch):
        _assert_topdocs_identical(td, s.search_single(q, k=N_DOCS), ctx=repr(q))
    # the deleted + absent terms return empty results with the right shape
    assert batch[0].total_hits == 0
    assert batch[3].total_hits == 0
    assert batch[3].doc_ids.dtype == np.int64


def test_sort_and_facet_include_local_doc_zero():
    """Padding rows alias local doc 0 (docs=0, valid=False); the scatter
    must not erase a real match of doc 0 (regression: .set -> .max)."""
    eng = SearchEngine("ram")
    texts = ["target alpha", "filler beta", "target gamma", "filler d", "target e"]
    for i, text in enumerate(texts):
        eng.add({"body": text}, {"month": i % 3, "ts": i})
    eng.reopen()
    td = eng.search(SortQuery(TermQuery("body", "target"), "ts"), k=10)
    assert td.total_hits == 3
    assert sorted(td.doc_ids.tolist()) == [0, 2, 4]
    fd = eng.search(FacetQuery(TermQuery("body", "target"), "month", 3))
    assert fd.total_hits == 3
    np.testing.assert_array_equal(fd.facets, [1.0, 1.0, 1.0])  # m0,m2,m1


def test_crash_recover_preserves_pallas_flag(tmp_path):
    eng = SearchEngine("byte-pmem", str(tmp_path / "p"), use_pallas=True)
    for i in range(12):
        eng.add({"body": f"alpha w{i % 3}"}, {"month": i % 12})
    eng.reopen()
    eng.commit()
    eng2 = eng.crash_and_recover()
    assert eng2.use_pallas and eng2.manager.use_pallas
    assert eng2.searcher.use_pallas
    assert eng2.search(TermQuery("body", "alpha")).total_hits == 12


def test_facet_parity_with_out_of_range_bins():
    """Negative doc-values clip to bin 0 and overflow bins drop — the
    batched path must share bincount semantics with the oracle."""
    eng = SearchEngine("ram")
    for i in range(40):
        eng.add({"body": f"alpha w{i % 4}"}, {"month": i % 15 - 2})  # -2..12
    eng.reopen()
    queries = [
        FacetQuery(None, "month", 12),
        FacetQuery(TermQuery("body", "alpha"), "month", 12),
    ]
    batch = eng.search_batch(queries, k=12)
    s = eng.searcher
    for q, td in zip(queries, batch):
        _assert_topdocs_identical(td, s.search_single(q, k=12), ctx=repr(q))


def test_planner_groups_by_family():
    queries = _mixed_batch()
    plan = plan_batch(queries)
    assert plan.n_queries == len(queries)
    # every query lands in exactly one group, original order recoverable
    seen = sorted(i for g in plan.groups for i in g.indices)
    assert seen == list(range(len(queries)))
    for g in plan.groups:
        assert all(family_key(q) == g.key for q in g.queries)
    # terms share one group; and/or booleans are distinct executor signatures
    kinds = [g.key[0] for g in plan.groups]
    assert kinds.count("term") == 1
    assert kinds.count("bool") == 2


def test_pallas_batch_matches_pallas_single():
    eng = _build("ram")
    from repro.core.search import Searcher

    s = Searcher(eng.writer.segments, use_pallas=True)
    queries = [TermQuery("body", _word(i)) for i in (1, 2, 20)]
    batch = s.search_batch(queries, k=10)
    for q, td in zip(queries, batch):
        _assert_topdocs_identical(td, s.search_single(q, k=10), ctx=repr(q))


# ---------------------------------------------------------------------------
# SegmentDeviceCache
# ---------------------------------------------------------------------------


def test_nrt_reopen_uploads_only_new_segment():
    eng = SearchEngine("ram")
    for i, (fields, dv) in enumerate(
        synthetic_corpus(CorpusConfig(n_docs=200, vocab=300, seed=3))
    ):
        eng.add(fields, dv)
        if (i + 1) % 50 == 0:
            eng.flush()
    eng.reopen()
    eng.search(TermQuery("body", _word(1)))
    stats = eng.device_cache.stats
    base_segments = stats.segment_uploads
    base_arrays = stats.array_uploads
    assert base_segments == len(eng.writer.segments)

    # one more flush: the reopen must upload ONLY the new segment's arrays
    for fields, dv in list(
        synthetic_corpus(CorpusConfig(n_docs=10, vocab=300, seed=4))
    ):
        eng.add(fields, dv)
    eng.flush()  # cut the segment; default reopen keeps docs buffer-resident
    eng.reopen()
    assert stats.segment_uploads == base_segments + 1
    new_seg = eng.writer.segments[-1]
    # doc_lens + live + one column per doc-values field
    assert stats.array_uploads == base_arrays + 2 + len(new_seg.doc_values)

    # searching after the reopen hits the resident buffers, no re-upload
    arrays_before = stats.array_uploads
    eng.search_batch([TermQuery("body", _word(1)), RangeQuery("month", 0, 6)])
    assert stats.array_uploads == arrays_before


def test_delete_refreshes_only_live_bitmap():
    eng = SearchEngine("ram")
    for fields, dv in synthetic_corpus(CorpusConfig(n_docs=100, vocab=300, seed=5)):
        eng.add(fields, dv)
    eng.flush()  # the delete below must tombstone a SEGMENT's bitmap
    eng.reopen()
    eng.search(TermQuery("body", _word(1)))
    stats = eng.device_cache.stats
    seg_uploads = stats.segment_uploads
    arrays = stats.array_uploads
    eng.delete("body", _word(2))
    eng.reopen()
    eng.search(TermQuery("body", _word(1)))
    assert stats.segment_uploads == seg_uploads  # no full re-upload
    assert stats.live_refreshes >= 1
    assert stats.array_uploads == arrays + 1  # the new live bitmap only


def test_merge_evicts_stale_segments():
    eng = SearchEngine("ram")
    cache = eng.device_cache
    docs = list(synthetic_corpus(CorpusConfig(n_docs=240, vocab=300, seed=6)))
    for i, (fields, dv) in enumerate(docs):
        eng.add(fields, dv)
        if (i + 1) % 20 == 0:
            # flush+reopen per 20 docs: segments become device-resident, so
            # the eventual tiered merge must evict the merged-away ones
            eng.flush()
            eng.reopen()
    live_names = {s.name for s in eng.writer.segments}
    assert set(cache._store) == live_names
    assert cache.stats.evictions > 0  # merged-away segments were dropped


def test_stale_searcher_does_not_repollute_cache():
    """A retained pre-merge Searcher must not re-insert merged-away
    segments into the shared cache (double-residency churn)."""
    eng = SearchEngine("ram")
    docs = list(synthetic_corpus(CorpusConfig(n_docs=240, vocab=300, seed=6)))
    for i, (fields, dv) in enumerate(docs[:200]):
        eng.add(fields, dv)
        if (i + 1) % 20 == 0:
            eng.flush()
            eng.reopen()
    assert len(eng.writer.segments) == 10  # at the merge_factor threshold
    stale = eng.searcher  # pre-merge point-in-time view
    stale.search(TermQuery("body", _word(1)))  # make its segments resident
    for fields, dv in docs[200:]:
        eng.add(fields, dv)
    eng.flush()
    eng.reopen()  # 11th flush triggers the tiered merge + eviction
    cache = eng.device_cache
    live_names = {s.name for s in eng.writer.segments}
    assert set(cache._store) <= live_names
    stale.search(TermQuery("body", _word(1)))  # old view still queryable
    assert set(cache._store) <= live_names  # ...without re-inserting
    assert cache.stats.transient_uploads > 0
    # the stale view memoizes its own uploads: a second query re-uploads
    # nothing (transient count flat, searcher-local dict serves the hits)
    transients = cache.stats.transient_uploads
    arrays = cache.stats.array_uploads
    stale.search(TermQuery("body", _word(2)))
    assert cache.stats.transient_uploads == transients
    assert cache.stats.array_uploads == arrays


def test_searcher_generations_share_cache():
    eng = SearchEngine("ram")
    for fields, dv in synthetic_corpus(CorpusConfig(n_docs=50, vocab=200, seed=8)):
        eng.add(fields, dv)
    eng.reopen()
    s1 = eng.searcher
    for fields, dv in synthetic_corpus(CorpusConfig(n_docs=10, vocab=200, seed=9)):
        eng.add(fields, dv)
    eng.reopen()
    s2 = eng.searcher
    assert s1 is not s2  # point-in-time views
    assert s1.device_cache is s2.device_cache is eng.device_cache


def test_standalone_cache_api():
    cache = SegmentDeviceCache()
    assert len(cache) == 0 and "x" not in cache
    cache.retain([])
    assert cache.stats.evictions == 0
