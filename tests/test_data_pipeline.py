"""Data substrate: corpus determinism, LM batches, samplers, recsys streams."""

import numpy as np

from repro.data.corpus import CorpusConfig, synthetic_corpus
from repro.data.graph import NeighborSampler, synthetic_graph
from repro.data.lm import lm_batches
from repro.data.recsys_data import bert4rec_batches, ctr_batches, twotower_batches


def test_corpus_deterministic():
    a = list(synthetic_corpus(CorpusConfig(n_docs=20, seed=3)))
    b = list(synthetic_corpus(CorpusConfig(n_docs=20, seed=3)))
    assert a == b
    c = list(synthetic_corpus(CorpusConfig(n_docs=20, seed=4)))
    assert a != c


def test_lm_batches_shapes():
    it = lm_batches(batch=4, seq=32, vocab=1000, n_docs=500)
    b = next(it)
    assert b["tokens"].shape == (4, 32)
    assert b["labels"].shape == (4, 32)
    # labels are next-token shifted
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    assert b["tokens"].min() >= 1
    assert b["tokens"].max() < 1000


def test_neighbor_sampler_static_shapes():
    g = synthetic_graph(2000, 10, 8, 4, seed=0)
    s = NeighborSampler(g, fanout=(5, 3), seed=1)
    n_static = 32 * (1 + 5 + 15)
    e_static = 32 * 5 * (1 + 3)
    for _ in range(3):
        seeds = np.random.default_rng(0).choice(2000, 32, replace=False)
        sub = s.sample(seeds)
        assert sub["node_feats"].shape == (n_static, 8)
        assert sub["edge_index"].shape == (2, e_static)
        assert sub["label_mask"].sum() == 32  # supervise seeds only
        # all edges reference in-range local ids
        assert sub["edge_index"].max() < n_static


def test_ctr_batches():
    it = ctr_batches(64, 10, 1000, seed=0)
    b = next(it)
    assert b["ids"].shape == (64, 10)
    # field offsets: ids of field j live in [j*1000, (j+1)*1000)
    for j in range(10):
        assert (b["ids"][:, j] // 1000 == j).all()
    assert set(np.unique(b["label"])) <= {0, 1}


def test_bert4rec_batches_mask():
    b = next(bert4rec_batches(8, 100, 20, seed=0))
    m = 20 // 5
    assert b["mask_positions"].shape == (8, m)
    # masked positions carry the MASK id; labels are the original items
    taken = np.take_along_axis(b["seq"], b["mask_positions"], axis=1)
    assert (taken == 101).all()
    assert (b["mask_labels"] >= 1).all() and (b["mask_labels"] <= 100).all()


def test_twotower_batches():
    b = next(twotower_batches(16, 1000, 500, 8, 4, seed=0))
    assert b["user_hist"].shape == (16, 8)
    assert b["item_feats"].shape == (16, 4)
