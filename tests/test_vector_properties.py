"""Hypothesis properties: dense vectors under durability + compaction.

Two invariants the tentpole must hold under arbitrary schedules:

  * torn WAL writes — a crash may tear the in-flight (un-acked) record at
    ANY byte; recovery must rebuild exactly the fully-acked batches, and
    the recovered index must answer vector + hybrid queries BIT-identically
    to a never-crashed writer fed only the acked prefix (the ``_vec``
    columns replay through ``extend_raw_vectors`` into the same block
    layout);

  * merge with deletes — however flushes slice the corpus and whichever
    docs die, a tiered merge must keep every surviving doc's vector row
    attached to its own identity: row j of the merged ``_vec`` column is
    exactly the vector indexed by row j's doc-number column, never a
    neighbour's (off-by-one remaps are precisely what a prefix-sum
    compaction bug produces).

``hypothesis`` is an optional test dependency (same convention as
``test_wal_torn.py``): the module skips itself when absent; the
deterministic twins live in ``test_vector_search.py``.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import SearchEngine
from repro.core.search import HybridQuery, TermQuery, VectorQuery
from repro.core.segment import merge_segments
from repro.core.writer import VECTOR_FIELD

pytestmark = pytest.mark.vector

DIM = 8
TOKENS = [f"w{i}" for i in range(10)]


def _vec_of(n: int) -> np.ndarray:
    """Deterministic per-doc vector: recognisable, no two docs equal."""
    base = np.arange(DIM, dtype=np.float32)
    return (base + np.float32(n) * 0.25 + np.float32((n % 5) - 2)).astype(
        np.float32
    )


def _docs(sizes):
    """Batches from drawn sizes; doc n carries token soup + vector(n)
    (every 6th doc vectorless, so zero rows ride the schedules too)."""
    out = []
    n = 0
    for size in sizes:
        batch = []
        for _ in range(size):
            toks = " ".join(
                TOKENS[(n + j) % len(TOKENS)] for j in range(1 + n % 4)
            )
            dv = {"month": float(n % 12), "docno": float(n)}
            if n % 6 != 4:
                dv[VECTOR_FIELD] = _vec_of(n)
            batch.append(({"body": f"{toks} common"}, dv))
            n += 1
        out.append(batch)
    return out


def _tear(directory, frac):
    """Truncate the heap between the committed watermark and the tail,
    zero-filling back to size (the only region a power loss can tear)."""
    heap = directory.heap
    lo, hi = heap.committed, max(heap.tail, heap.committed)
    cut = int(lo + frac * (hi - lo))
    cap = heap.capacity
    heap.close()
    with open(heap.path, "r+b") as f:
        f.truncate(cut)
        f.truncate(cap)


def _inflight_batch(writer, batch):
    """Issue one more batch's stores WITHOUT the ack barrier — the state a
    mid-batch crash tears (vector columns included)."""
    w = writer
    d0, n0, p0 = len(w._buf_doc_lens), len(w._buf), w._buf.n_positions
    v0, c0 = w._buf.vec_doc.n, w._buf.vec.n
    for fields, dv in batch:
        w._append_document(fields, dv)
    th, dl, fr, po, ps = w._buf.columns()
    meta = {"kind": "batch", "base": d0, "dv_keys": []}
    arrays = {
        "term_hash": th[n0:], "doc_local": dl[n0:], "freq": fr[n0:],
        "pos_offset": po[n0:], "positions": ps[p0:],
        "doc_lens": np.asarray(w._buf_doc_lens[d0:], dtype=np.int64),
        "dv_key": np.empty(0, np.int32),
        "dv_doc": np.empty(0, np.int32),
        "dv_val": np.empty(0, np.float64),
    }
    if w._buf.vec_dim:
        vc, vd, dim = w._buf.vector_columns()
        meta["vec_dim"] = int(dim)
        arrays["vec"] = np.asarray(vc[c0:])
        arrays["vec_doc"] = np.asarray(vd[v0:])
    w.directory._wal.append(meta, arrays, durable=False)


def _probe_queries():
    qs = [
        VectorQuery(tuple(float(x) for x in _vec_of(2)), metric="dot"),
        VectorQuery(tuple(float(x) for x in _vec_of(7)), metric="cosine"),
        HybridQuery(
            TermQuery("body", TOKENS[1]),
            VectorQuery(tuple(float(x) for x in _vec_of(3)), metric="cosine"),
            alpha=0.4,
        ),
    ]
    return qs


@settings(max_examples=10, deadline=None)
@given(
    sizes=st.lists(st.integers(1, 8), min_size=1, max_size=4),
    inflight=st.integers(1, 6),
    frac=st.floats(0.0, 1.0),
)
def test_torn_write_recovers_acked_vectors(tmp_path_factory, sizes, inflight, frac):
    tmp = tmp_path_factory.mktemp("vec-torn")
    eng = SearchEngine("byte-pmem", str(tmp / "d"), use_wal=True)
    acked = _docs(sizes)
    for b in acked:
        eng.add_documents(b)
    _inflight_batch(eng.writer, _docs([inflight])[0])
    path = eng.directory.path
    _tear(eng.directory, frac)

    rec = SearchEngine("byte-pmem", path, use_wal=True)
    n_acked = sum(sizes)
    assert rec.writer.buffered_docs == n_acked  # whole batches, none extra
    rec.reopen()
    ref = SearchEngine("ram")
    for b in acked:
        ref.add_documents(b)
    ref.reopen()
    k = max(n_acked, 1)
    for q in _probe_queries():
        ta = ref.search(q, k=k)
        tb = rec.search(q, k=k)
        assert ta.total_hits == tb.total_hits, repr(q)
        np.testing.assert_array_equal(ta.doc_ids, tb.doc_ids, err_msg=repr(q))
        np.testing.assert_array_equal(ta.scores, tb.scores, err_msg=repr(q))


@settings(max_examples=15, deadline=None)
@given(
    sizes=st.lists(st.integers(1, 30), min_size=2, max_size=5),
    dead_mod=st.integers(2, 7),
    dead_off=st.integers(0, 6),
)
def test_merge_never_mixes_rows_across_ids(sizes, dead_mod, dead_off):
    """After merging arbitrarily-sliced segments with an arbitrary delete
    pattern, every merged row's vector is ITS OWN doc's vector."""
    eng = SearchEngine("ram")
    n = 0
    for size in sizes:
        for _ in range(size):
            toks = " ".join(
                TOKENS[(n + j) % len(TOKENS)] for j in range(1 + n % 4)
            )
            dv = {"docno": float(n)}
            if n % 6 != 4:
                dv[VECTOR_FIELD] = _vec_of(n)
            eng.add({"body": f"{toks} common"}, dv)
            n += 1
        eng.flush()
    # kill docno % dead_mod == dead_off via per-segment live bitmaps
    segs = []
    for seg in eng.writer.segments:
        docno = seg.doc_values["docno"].astype(np.int64)
        segs.append(seg.with_live(seg.live & ~((docno % dead_mod) == dead_off)))
    merged = merge_segments("m", 0, segs)
    docno = merged.doc_values["docno"].astype(np.int64)
    vecs = merged.doc_values[VECTOR_FIELD]
    assert vecs.shape == (len(docno), DIM)
    for j in range(len(docno)):
        d = int(docno[j])
        assert d % dead_mod != dead_off  # dead docs are compacted away
        expect = _vec_of(d) if d % 6 != 4 else np.zeros(DIM, np.float32)
        np.testing.assert_array_equal(
            vecs[j], expect, err_msg=f"row {j} docno {d}"
        )
